"""The ublk-style public block-device API (core/blockdev.py) and the
backend registry (core/backends.py).

Contracts (ISSUE 4 acceptance):

1. **byte equivalence** — interleavings of byte-level ``pread``/``pwrite``/
   ``discard``/``snapshot``/``clone``/``delete`` through ``Volume`` are
   bit-identical to a host bytearray reference AND to the ``ChainedStore``
   reference walk, parametrized over every registered backend.
2. **single-dispatch contract through the API** — driving the ring backend
   via ``VolumeManager`` keeps one compiled program per batch-class
   signature and one device fetch per pump (the test_ring dispatch tests,
   extended to the new surface).
3. **submission-boundary validation** — mixed-kind batches: control kinds
   are rejected at submit on data-only backends with the queued data
   requests unharmed, and ride in-band on the ring.
4. **unaligned byte I/O property test** (hypothesis, importorskip-gated) —
   random byte spans (page-edge, sub-block, cross-extent) against a
   host-side bytearray reference on ``backend="ring"`` and ``"fused"``.
5. registry extensibility; serving's control-plane embedding.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import Engine, EngineConfig, Request
from repro.core.backends import (available_backends, make_backend,
                                 register_backend)
from repro.core.blockdev import IOFuture, Volume, VolumeManager
from repro.core.engine import ChainedStore

# the six engine backends of the acceptance matrix + the host oracle
BACKENDS = [("upstream", 1), ("loop", 1), ("slots", 1), ("fused", 1),
            ("sharded", 2), ("ring", 2), ("host", 1)]

BB = 8          # block_bytes (payload_elems)
PB = 4          # page_blocks -> page_bytes = 32
PAGES = 8       # capacity = 256 bytes


def _mgr(backend: str, n_shards: int = 1, **kw) -> VolumeManager:
    base = dict(backend=backend, n_shards=n_shards, payload_elems=BB,
                page_blocks=PB, max_pages=PAGES, n_extents=256,
                max_volumes=16, batch=16, n_replicas=2)
    base.update(kw)
    return VolumeManager(**base)


def _pat(seed: int, n: int) -> bytes:
    return bytes((seed * 37 + i) % 251 for i in range(n))


# ---------------------------------------------------------------------------
# 1. byte equivalence on every registered backend
# ---------------------------------------------------------------------------
class _Refs:
    """Host bytearray + ChainedStore double-reference for one manager."""

    def __init__(self, mgr: VolumeManager):
        self.mgr = mgr
        self.chained = ChainedStore((BB,))
        self.bufs = {}          # vid -> bytearray
        self.cmap = {}          # vid -> chained volume id

    def new_vol(self) -> Volume:
        v = self.mgr.create()
        self.bufs[v.vid] = bytearray(self.mgr.capacity)
        self.cmap[v.vid] = self.chained.create_volume()
        return v

    def _mirror_blocks(self, vid: int, off: int, n: int) -> None:
        """Write the ref buffer's current block contents covering
        [off, off+n) into the chained mirror."""
        buf = self.bufs[vid]
        first, last = off // BB, (off + n - 1) // BB
        for ab in range(first, last + 1):
            blk = bytes(buf[ab * BB:(ab + 1) * BB])
            self.chained.write(self.cmap[vid], ab // PB, ab % PB,
                               np.frombuffer(blk, np.uint8)
                               .astype(np.float32))

    def write(self, v: Volume, off: int, data: bytes) -> IOFuture:
        fut = v.pwrite(off, data)
        self.bufs[v.vid][off:off + len(data)] = data
        self._mirror_blocks(v.vid, off, len(data))
        return fut

    def discard(self, v: Volume, off: int, n: int) -> IOFuture:
        fut = v.discard(off, n)
        self.bufs[v.vid][off:off + n] = bytes(n)
        pby = self.mgr.page_bytes
        ff, lf = -(-off // pby), (off + n) // pby
        edges = ([(off, ff * pby), (lf * pby, off + n)] if ff < lf
                 else [(off, off + n)])
        if ff < lf:
            for p in range(ff, lf):
                self.chained.unmap(self.cmap[v.vid], p)
        for a, b in edges:
            if b > a:
                self._mirror_blocks(v.vid, a, b - a)
        return fut

    def read_expect(self, v: Volume, off: int, n: int):
        """Submit an async read; expected value is the reference content at
        SUBMISSION time (sequential per-volume semantics)."""
        return v.pread(off, n), bytes(self.bufs[v.vid][off:off + n])

    def snapshot(self, v: Volume):
        out = v.snapshot()
        self.chained.snapshot(self.cmap[v.vid])
        return out

    def clone(self, v: Volume) -> Volume:
        child = v.clone()
        assert child is not None
        self.bufs[child.vid] = bytearray(self.bufs[v.vid])
        self.cmap[child.vid] = self.chained.clone(self.cmap[v.vid])
        return child

    def delete(self, v: Volume) -> None:
        self.chained.delete_volume(self.cmap.pop(v.vid))
        del self.bufs[v.vid]
        self.mgr.delete(v)

    def check_all(self) -> None:
        """Every live volume: full-device byte read == bytearray ref, and
        the ChainedStore walk agrees block by block (holes read zeros)."""
        self.mgr.flush()
        for vid, buf in self.bufs.items():
            got = self.mgr.open(vid).read(0, self.mgr.capacity)
            assert got == bytes(buf), f"vid {vid} device/bytearray mismatch"
            for ab in range(len(buf) // BB):
                want = bytes(buf[ab * BB:(ab + 1) * BB])
                w = self.chained.read(self.cmap[vid], ab // PB, ab % PB)
                w = (bytes(BB) if w is None
                     else np.asarray(w).astype(np.uint8).tobytes())
                assert w == want, f"vid {vid} block {ab} chained mismatch"


@pytest.mark.parametrize("backend,shards", BACKENDS)
def test_byte_equivalence_interleaved(backend, shards):
    mgr = _mgr(backend, shards)
    refs = _Refs(mgr)
    v1, v2 = refs.new_vol(), refs.new_vol()

    pending = []
    # aligned + unaligned writes, async, interleaved across volumes
    pending.append(refs.write(v1, 0, _pat(1, 17)))       # unaligned tail
    pending.append(refs.write(v2, 5, _pat(2, 11)))       # unaligned head+tail
    pending.append(refs.write(v1, 13, _pat(3, 9)))       # overlaps in flight
    r1, e1 = refs.read_expect(v1, 3, 20)                 # async read
    pending.append(refs.write(v1, 24, _pat(4, 48)))      # page-crossing span
    r2, e2 = refs.read_expect(v2, 0, 32)
    assert all(f.result() is not None for f in pending)
    assert r1.result() == e1 and r2.result() == e2
    refs.check_all()

    # snapshot -> CoW overwrite -> clone divergence
    refs.snapshot(v1)
    refs.write(v1, 2, _pat(5, 40))                       # CoW vs snapshot
    c1 = refs.clone(v1)
    refs.write(c1, 0, _pat(6, 23))                       # child diverges
    refs.write(v1, 64, _pat(7, 16))                      # parent diverges
    refs.check_all()

    # discard: sub-block, partial-page, and full-page (TRIM) spans
    refs.write(v2, 32, _pat(8, 96))
    refs.discard(v2, 34, 3)                              # sub-block
    refs.discard(v2, 40, 20)                             # partial page
    refs.discard(v1, 30, 70)                             # edges + full pages
    refs.check_all()

    # delete a volume, create a fresh one, keep going
    refs.delete(v2)
    v3 = refs.new_vol()
    refs.write(v3, 7, _pat(9, 33))
    refs.check_all()


@pytest.mark.parametrize("backend,shards", [("ring", 2), ("fused", 1)])
def test_large_span_fans_out_and_completes_on_flush(backend, shards):
    """One user call -> many SQEs, completed by ONE flush (no per-block
    host round trips); bytes round-trip exactly."""
    mgr = _mgr(backend, shards)
    v = mgr.create()
    data = _pat(11, 5 * mgr.page_bytes + 13)             # cross-extent span
    fut = v.pwrite(3, data)
    rfut = v.pread(3, len(data))
    assert not fut.done() or backend == "host"
    mgr.flush()
    assert fut.done() and rfut.done()
    assert fut.result() == len(data)
    assert rfut.result() == data


# ---------------------------------------------------------------------------
# 2. dispatch accounting through the public API (test_ring extended)
# ---------------------------------------------------------------------------
def test_api_one_program_per_class_signature(monkeypatch):
    mgr = _mgr("ring", 2, n_queues=1)
    pool = mgr.engine.pool
    vols = [mgr.create() for _ in range(4)]

    def traffic():
        futs = []
        for i, v in enumerate(vols):
            futs.append(v.pwrite(0, _pat(i, mgr.page_bytes)))    # page span
            futs.append(v.pread(i * BB, 3 * BB))
        vols[0].snapshot()                                       # in-band vol
        mgr.discard(vols[1], 0, mgr.page_bytes)                  # in-band unmap
        mgr.flush()
        for f in futs:
            f.result()
    traffic()
    assert all(v == 1 for v in pool.trace_counts.values()), pool.trace_counts
    before = dict(pool.trace_counts)
    d0 = pool.dispatches
    traffic()                       # more byte traffic: ZERO new programs
    assert pool.trace_counts == before
    assert pool.dispatches > d0

    # one device fetch per pump, even with a span fan-out + control aboard
    v = vols[2]
    fut = v.pwrite(0, _pat(3, mgr.page_bytes))
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: (calls.append(1), real(x))[1])
    done = pool.pump()
    assert done == PB               # the whole page span in one pump
    assert len(calls) == 1, f"expected 1 completion fetch, saw {len(calls)}"
    monkeypatch.undo()
    assert fut.result() == mgr.page_bytes


# ---------------------------------------------------------------------------
# 3. submission-boundary validation (mixed-kind batches)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,shards", [("upstream", 1), ("loop", 1),
                                            ("slots", 1), ("fused", 1),
                                            ("sharded", 2), ("host", 1)])
def test_control_rejected_at_submit_data_survives(backend, shards):
    """On data-only backends a control kind is rejected AT THE SUBMISSION
    BOUNDARY — before enqueue — so data requests already queued alongside
    it are not lost, and the engine's control() surface still works."""
    mgr = _mgr(backend, shards)
    v = mgr.create()
    eng = mgr.engine
    w = Request(req_id=0, kind="write", volume=v.vid, page=0, block=0,
                payload=np.full((BB,), 7.0, np.float32))
    eng.submit(w)
    for kind in ("snapshot", "clone", "unmap", "noop"):
        with pytest.raises(ValueError):
            eng.submit(Request(req_id=1, kind=kind, volume=v.vid))
    assert eng.depth() == 1         # the data request is intact
    assert eng.drain() == 1 and w.status == 0
    # the same op goes through the control plane instead
    mgr.snapshot(v)
    assert v.read(0, BB) == bytes(bytearray([7] * BB))


def test_mixed_kind_batch_inband_on_ring():
    """The ring accepts the same mixed batch in ONE submission stream."""
    mgr = _mgr("ring", 2, n_queues=1)
    v = mgr.create()
    fut = v.pwrite(0, _pat(1, 2 * BB))
    snap = Request(req_id=mgr._rid(v.vid), kind="snapshot", volume=v.vid)
    mgr.engine.submit(snap)
    fut2 = v.pwrite(0, _pat(2, BB))          # CoW against the in-band snap
    mgr.flush()
    assert fut.result() == 2 * BB and fut2.result() == BB
    assert snap.status == 0 and snap.result >= 0
    assert v.read(0, 2 * BB) == _pat(2, BB) + _pat(1, 2 * BB)[BB:]


# ---------------------------------------------------------------------------
# 5. registry + embedding surfaces
# ---------------------------------------------------------------------------
def test_registry_lists_and_rejects():
    names = available_backends()
    for name in ("loop", "slots", "fused", "sharded", "ring", "upstream",
                 "host"):
        assert name in names
    with pytest.raises(ValueError, match="registered"):
        make_backend("nope", EngineConfig())
    with pytest.raises(ValueError, match="registered"):
        Engine(EngineConfig(comm="nope"))


def test_register_custom_backend_roundtrip():
    """register_backend() is the extension point: a custom backend drives
    the full byte API without touching engine.py."""
    from repro.core.backends import HostStateBackend

    @register_backend("test-custom")
    class Custom(HostStateBackend):
        pass

    try:
        mgr = _mgr("test-custom")
        v = mgr.create()
        v.write(3, b"custom backend")
        assert v.read(3, 14) == b"custom backend"
        assert isinstance(mgr.engine.impl, Custom)
    finally:
        from repro.core import backends as B
        B._REGISTRY.pop("test-custom", None)


def test_engine_facade_legacy_surface():
    """The façade keeps the legacy attribute surface (shim acceptance)."""
    eng = Engine(EngineConfig(comm="ring", n_shards=2, payload_shape=(BB,),
                              n_extents=128, max_pages=16))
    assert eng.pool is not None and eng.pool is eng.impl
    assert eng.backend is eng.pool.backend
    assert eng.frontend is eng.pool.frontend
    unfused = Engine(EngineConfig(comm="slots", payload_shape=(BB,)))
    assert unfused.pool is None
    assert unfused.backend is not None          # the ReplicaGroup
    up = Engine(EngineConfig(comm="upstream", payload_shape=(BB,)))
    assert up.pool is None and up.backend is None
    vol = up.create_volume()
    r = Request(req_id=0, kind="write", volume=vol, page=0, block=0,
                payload=np.ones((BB,), np.float32))
    up.submit(r)
    assert up.drain() == 1 and r.status == 0


def test_volumemanager_stats_and_bounds():
    mgr = _mgr("ring", 2)
    v = mgr.create()
    with pytest.raises(ValueError):
        v.pread(mgr.capacity - 2, 4)            # out of bounds
    with pytest.raises(ValueError):
        v.pwrite(-1, b"x")
    assert v.pwrite(0, b"").result() == 0       # zero-length ops complete
    assert v.pread(5, 0).result() == b""
    st_ = mgr.stats()
    assert st_["backend"] == "ring" and st_["queued"] == 0


def test_serving_allocates_pages_through_volumemanager():
    """The serving engine's control plane is a VolumeManager over the host
    backend: alloc_pages returns WriteOps for the external KV data plane."""
    import jax.numpy as jnp
    mgr = VolumeManager(backend="host", null_storage=True, n_extents=64,
                        max_volumes=8, max_pages=4, page_blocks=4,
                        payload_elems=1)
    v = mgr.create()
    ops = mgr.alloc_pages(jnp.asarray([v.vid], jnp.int32),
                          jnp.asarray([0], jnp.int32),
                          mask=jnp.asarray([True]))
    assert bool(ops.ok[0]) and int(ops.dst[0]) >= 0
    assert int(mgr.state.table[v.vid, 0]) == int(ops.dst[0])
    child = mgr.clone(v)
    assert child is not None and child.vid != v.vid
    mgr.delete(child)
    mgr.delete(v)
