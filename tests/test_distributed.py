"""Distribution tests: planner rules + a subprocess dry-run on 8 fake devices
(XLA_FLAGS must be set before jax import, so these lower in a child python).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# feature gates: these tests exercise jax APIs newer than some pinned
# environments (e.g. jax 0.4.37 has neither jax.sharding.AxisType nor
# top-level jax.shard_map) — skip rather than fail there
needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available in this jax")
needs_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="top-level jax.shard_map not available in this jax")


@needs_axis_type
def test_planner_divisibility_fallbacks():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import ExecutionPlan, get_config
    from repro.distributed.planner import Planner, pick

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # pick() itself
    assert tuple(pick(mesh, (64, 32), [P("data", "model")])) == ("data", "model")

    mesh16 = None
    # logical divisibility checks against the production shape without
    # building a 256-device mesh: use a fake mesh-shape shim
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    # granite vocab 49155 is not 16-divisible -> embed falls back to d_model
    spec = pick(fm, (49155, 4096), [P("model", None), P(None, "model")])
    assert tuple(spec) == (None, "model")
    # gemma2 kv proj 4*256=1024 divides 16 -> column parallel holds
    spec = pick(fm, (2304, 1024), [P(None, "model")])
    assert tuple(spec) == (None, "model")
    # granite-moe 40 experts don't divide 16 -> fall back to per-expert d_ff
    spec = pick(fm, (40, 1536, 512),
                [P("model", None, None), P(None, None, "model")])
    assert tuple(spec) == (None, None, "model")
    # deepseek 256 experts divide -> expert parallel
    spec = pick(fm, (256, 7168, 2048),
                [P("model", None, None), P(None, None, "model")])
    assert tuple(spec) == ("model", None, None)


@needs_axis_type
def test_all_param_leaves_get_specs():
    import jax
    from repro.configs import ALL_ARCHS, ExecutionPlan, get_config, smoke_config
    from repro.distributed.planner import Planner
    from repro.models import init_params

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    plan = ExecutionPlan()
    for arch in ALL_ARCHS:
        cfg = smoke_config(arch)
        shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                                jax.random.PRNGKey(0))
        planner = Planner(mesh, cfg, plan)
        specs = planner.tree_specs(shapes)
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")
            or x.__class__.__name__ == "PartitionSpec"))
        n_leaves = len(jax.tree.leaves(shapes))
        assert n_specs == n_leaves, arch


@needs_axis_type
@needs_shard_map
@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("gemma2-2b", "train_4k"),
    ("granite-moe-3b-a800m", "decode_32k"),
])
def test_dryrun_cell_compiles_on_8_devices(arch, shape):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from repro.configs import get_config, SHAPES
        from repro.launch.mesh import make_mesh
        from repro.launch.specs import build_cell
        mesh = make_mesh((2, 4), ("data", "model"))
        cell = build_cell(get_config("{arch}"), SHAPES["{shape}"], mesh)
        co = jax.jit(cell.step, donate_argnums=cell.donate).lower(*cell.args).compile()
        cost = co.cost_analysis()
        print(json.dumps({{"flops": cost.get("flops", 0.0)}}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "flops" in out.stdout


def test_collective_parser_on_synthetic_hlo():
    from repro.utils import hlo as H
    text = textwrap.dedent("""\
    HloModule jit_f

    %cond (p: (s32[], f32[8])) -> pred[] {
      %gte = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(10)
      ROOT %lt = pred[] compare(%gte, %c), direction=LT
    }

    %body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %gte0 = s32[] get-tuple-element(%p), index=0
      %gte1 = f32[8]{0} get-tuple-element(%p), index=1
      %ar = f32[8]{0} all-reduce(%gte1), replica_groups={}, to_apply=%sum
      ROOT %t = (s32[], f32[8]) tuple(%gte0, %ar)
    }

    ENTRY %main (x: f32[8]) -> f32[8] {
      %init = (s32[], f32[8]) tuple(s32[] constant(0), %x)
      %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
      %big = f32[16,128]{1,0} all-gather(%x), dimensions={0}
      ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
    }
    """)
    stats = H.collective_stats(text)
    assert stats["all-reduce"]["count"] == 10          # trip-multiplied
    assert stats["all-reduce"]["bytes"] == 10 * 32
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["bytes"] == 16 * 128 * 4
