"""Table III (this repo's extension): aggregate throughput vs shard count.

The paper's Tables I/II isolate per-layer wins for ONE engine instance;
this table measures the scale axis core/sharded.py adds: the same
multi-volume request stream served by an ``EnginePool`` with S ∈ {1,2,4,8}
engine shards, against the single-engine ``+fused`` column as baseline.
Every configuration serves the identical workload (``n_volumes`` volumes,
requests round-robin across them), so the S-axis shows pure dispatch
amortization + host/device overlap: one vmapped program per pump serves
all S shards, and the pipelined drain overlaps completion readback with
the next admission.

Expected shape (pinned loosely by ``--check``, used in CI smoke): S=1
matches ``+fused`` within noise (vmap over one shard + double-buffering is
not a cost), and aggregate ops/s grows with S up to ~4 as per-pump fixed
costs spread over S shards' batches.

CLI: ``python -m benchmarks.table3_shards --smoke --out BENCH.json --check``
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, List

import jax.numpy as jnp

from benchmarks.ladder import make_engine, measure_engine

SHARDS = (1, 2, 4, 8)


def run_table3(*, shards: Iterable[int] = SHARDS, n_requests: int = 1024,
               payload_elems: int = 16, pages: int = 64, n_volumes: int = 8,
               kind: str = "mixed", repeats: int = 3) -> Dict[str, object]:
    """Best-of-``repeats`` ops/s per configuration (the ladder's
    ``measure_engine`` protocol): shared runners inject multi-ms scheduling
    spikes, and max-over-repeats recovers the machine-limited number (jit
    compiles once on the first repeat)."""
    payload = jnp.ones((payload_elems,), jnp.float32)
    kw = dict(n_requests=n_requests, n_volumes=n_volumes, pages=pages,
              payload=payload, kind=kind)

    def best(make):
        return max(measure_engine(make(), **kw) for _ in range(repeats))

    fused = best(lambda: make_engine("+fused", "full_engine",
                                     payload_shape=(payload_elems,),
                                     max_pages=pages))
    sharded: Dict[int, float] = {}
    for s in shards:
        sharded[s] = best(lambda: make_engine(
            "+sharded", "full_engine", payload_shape=(payload_elems,),
            max_pages=pages, n_shards=s))
    return {"+fused": fused, "+sharded": sharded}


def check_scaling(res: Dict[str, object], *, floor: float = 0.7,
                  upto: int = 4) -> List[str]:
    """S=1 must match the single fused engine within noise, and aggregate
    throughput must not *lose* ground as shards are added up to ``upto``
    (monotone within the noise floor — shared runners are jittery, so the
    gate is a ratio, not strict monotonicity)."""
    problems = []
    sharded: Dict[int, float] = res["+sharded"]
    if 1 in sharded and sharded[1] < res["+fused"] * floor:
        problems.append(f"+sharded S=1 ({sharded[1]:.0f} ops/s) < {floor:g}x "
                        f"+fused ({res['+fused']:.0f} ops/s)")
    ss = sorted(s for s in sharded if s <= upto)
    for lo, hi in zip(ss, ss[1:]):
        if sharded[hi] < sharded[lo] * floor:
            problems.append(f"+sharded S={hi} ({sharded[hi]:.0f} ops/s) < "
                            f"{floor:g}x S={lo} ({sharded[lo]:.0f} ops/s)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry + S<=4 (CI per-PR run)")
    ap.add_argument("--kind", default="mixed",
                    choices=("mixed", "read", "write"))
    ap.add_argument("--out", default=None, help="write JSON (CI artifact)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if sharding loses to the fused baseline "
                         "or to fewer shards (see check_scaling)")
    args = ap.parse_args(argv)

    kw = (dict(shards=(1, 2, 4), n_requests=512) if args.smoke
          else dict(shards=SHARDS))
    res = run_table3(kind=args.kind, **kw)

    print(f"{'config':<14}{'ops/s':>12}")
    print(f"{'+fused':<14}{res['+fused']:>12.0f}")
    for s, ops in sorted(res["+sharded"].items()):
        print(f"{'+sharded S=' + str(s):<14}{ops:>12.0f}")

    if args.out:
        doc = {"bench": "table3_shards", "kind": args.kind,
               "smoke": bool(args.smoke), "params": {
                   k: v for k, v in kw.items() if k != "shards"},
               "shards": list(kw["shards"]), "ops_per_s": {
                   "+fused": res["+fused"],
                   "+sharded": {str(s): v
                                for s, v in res["+sharded"].items()}}}
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")

    if args.check:
        problems = check_scaling(res)
        if problems:
            print("REGRESSION:\n  " + "\n  ".join(problems), file=sys.stderr)
            return 1
        print("check OK: sharding holds the fused floor and scales")
    return 0


if __name__ == "__main__":
    sys.exit(main())
