import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Perf-iteration harness (§Perf hillclimb): re-lower one cell with plan
overrides and report the roofline-term deltas vs the recorded baseline.

  python -m benchmarks.perf_iter --arch granite-3-8b --shape train_4k \
      --set microbatches=4 remat=none --tag fewer-microbatches

Appends {baseline, variant, deltas} to results/perf_iters.json.
"""
import argparse
import json

from repro.launch.dryrun import run_cell


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--accounting", action="store_true", default=True)
    ap.add_argument("--out", default="results/perf_iters.json")
    args = ap.parse_args()

    overrides = parse_overrides(args.set)
    r = run_cell(args.arch, args.shape, multi_pod=False,
                 plan_overrides=overrides, accounting=args.accounting)
    keep = {k: r[k] for k in
            ("flops_per_device", "bytes_per_device",
             "collective_bytes_per_device", "t_compute", "t_memory",
             "t_collective", "bottleneck", "roofline_fraction",
             "hlo_useful_ratio", "compile_s", "plan") if k in r}
    rec = {"arch": args.arch, "shape": args.shape, "tag": args.tag,
           "overrides": overrides, **keep}
    print(json.dumps(rec, indent=1))
    hist = []
    if os.path.exists(args.out):
        hist = json.load(open(args.out))
    hist.append(rec)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    json.dump(hist, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
