"""Roofline table renderer: reads dry-run JSONs and prints the per-cell
three-term analysis (EXPERIMENTS.md §Roofline is generated from this).

Peaks come from ``repro.utils.machine.machine_profile`` — detected from the
jax device kind, overridable with ``--peak-flops``/``--hbm-bw``/``--link-bw``
(or ``REPRO_PEAK_FLOPS``/``REPRO_HBM_BW``/``REPRO_LINK_BW``), falling back
to the v5e assignment-brief numbers — so fractions aren't silently wrong off
the original TPU box. A ladder ``BENCH_*.json`` (its ``kernels`` key) renders
as the per-kernel achieved-vs-peak bytes/s table instead.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.utils.machine import MachineProfile, machine_profile

# back-compat module constants (the v5e defaults); consumers should resolve
# a MachineProfile instead
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load(path: str):
    with open(path) as f:
        return json.load(f)


def render(results: List[dict], *, only_single_pod: bool = True,
           profile: Optional[MachineProfile] = None) -> str:
    prof = profile or machine_profile()
    lines = [f"profile: {prof.name}  peak_flops={prof.peak_flops:.3g}  "
             f"hbm_bw={prof.hbm_bw:.3g}  link_bw={prof.link_bw:.3g}"
             + ("  (ASSUMED — pass --peak-flops/--hbm-bw or set "
                "REPRO_* env)" if prof.assumed else "")]
    hdr = (f"{'arch:shape':44s} {'kind':8s} {'t_comp(s)':>10s} {'t_mem(s)':>10s}"
           f" {'t_coll(s)':>10s} {'bottleneck':>11s} {'useful':>7s} {'roofl':>6s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "skipped":
            if not r.get("multi_pod", False):
                lines.append(f"{r['arch']+':'+r['shape']:44s} SKIP "
                             f"({r['skipped'][:70]})")
            continue
        if r.get("status") == "error":
            lines.append(f"{r['arch']+':'+r['shape']:44s} ERROR "
                         f"{r.get('error','')[:70]}")
            continue
        if only_single_pod and r.get("multi_pod"):
            continue
        lines.append(
            f"{r['arch']+':'+r['shape']:44s} {r['kind']:8s} "
            f"{r['t_compute']:10.4f} {r['t_memory']:10.4f} "
            f"{r['t_collective']:10.4f} {r['bottleneck']:>11s} "
            f"{r['hlo_useful_ratio']:7.3f} {r['roofline_fraction']:6.3f}")
    return "\n".join(lines)


def render_kernels(kernels: Dict[str, dict], *,
                   profile: Optional[MachineProfile] = None) -> str:
    """The ladder BENCH json's ``kernels`` key as an achieved-vs-peak
    bytes/s table (one row per registered DBS kernel)."""
    prof = profile or machine_profile()
    if isinstance(kernels.get("profile"), dict):
        p = kernels["profile"]
        prof = MachineProfile(p.get("name", prof.name),
                              p.get("peak_flops", prof.peak_flops),
                              p.get("hbm_bw", prof.hbm_bw),
                              p.get("link_bw", prof.link_bw),
                              p.get("assumed", prof.assumed))
    lines = [f"profile: {prof.name}  hbm_bw={prof.hbm_bw:.3g} B/s"
             + ("  (ASSUMED)" if prof.assumed else "")]
    hdr = (f"{'kernel':10s} {'write us':>9s} {'write B/s':>11s} "
           f"{'vs peak':>8s} {'read us':>9s} {'read B/s':>11s} "
           f"{'vs peak':>8s} {'identical':>9s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for name in sorted(kernels):
        row = kernels[name]
        if not isinstance(row, dict) or "write_us" not in row:
            continue
        lines.append(
            f"{name:10s} {row['write_us']:9.1f} "
            f"{row['write_bytes_per_s']:11.3g} "
            f"{row['write_bytes_per_s'] / prof.hbm_bw:8.2e} "
            f"{row['read_us']:9.1f} {row['read_bytes_per_s']:11.3g} "
            f"{row['read_bytes_per_s'] / prof.hbm_bw:8.2e} "
            f"{str(row.get('identical', '-')):>9s}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/roofline_single.json")
    ap.add_argument("--all-meshes", action="store_true")
    ap.add_argument("--peak-flops", type=float, default=None,
                    help="override peak flops/s per chip")
    ap.add_argument("--hbm-bw", type=float, default=None,
                    help="override HBM bytes/s per chip")
    ap.add_argument("--link-bw", type=float, default=None,
                    help="override ICI bytes/s per link")
    args = ap.parse_args()
    prof = machine_profile(args.peak_flops, args.hbm_bw, args.link_bw)
    doc = load(args.json)
    if isinstance(doc, dict) and "kernels" in doc:        # a ladder BENCH json
        print(render_kernels(doc["kernels"], profile=prof))
    elif isinstance(doc, dict):
        print(render_kernels(doc, profile=prof))
    else:
        print(render(doc, only_single_pod=not args.all_meshes, profile=prof))


if __name__ == "__main__":
    main()
