"""Roofline table renderer: reads dry-run JSONs and prints the per-cell
three-term analysis (EXPERIMENTS.md §Roofline is generated from this)."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)


def render(results: List[dict], *, only_single_pod: bool = True) -> str:
    lines = []
    hdr = (f"{'arch:shape':44s} {'kind':8s} {'t_comp(s)':>10s} {'t_mem(s)':>10s}"
           f" {'t_coll(s)':>10s} {'bottleneck':>11s} {'useful':>7s} {'roofl':>6s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "skipped":
            if not r.get("multi_pod", False):
                lines.append(f"{r['arch']+':'+r['shape']:44s} SKIP "
                             f"({r['skipped'][:70]})")
            continue
        if r.get("status") == "error":
            lines.append(f"{r['arch']+':'+r['shape']:44s} ERROR "
                         f"{r.get('error','')[:70]}")
            continue
        if only_single_pod and r.get("multi_pod"):
            continue
        lines.append(
            f"{r['arch']+':'+r['shape']:44s} {r['kind']:8s} "
            f"{r['t_compute']:10.4f} {r['t_memory']:10.4f} "
            f"{r['t_collective']:10.4f} {r['bottleneck']:>11s} "
            f"{r['hlo_useful_ratio']:7.3f} {r['roofline_fraction']:6.3f}")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/roofline_single.json")
    ap.add_argument("--all-meshes", action="store_true")
    args = ap.parse_args()
    print(render(load(args.json), only_single_pod=not args.all_meshes))


if __name__ == "__main__":
    main()
