"""The paper's §IV-A top-down methodology as a benchmark harness.

Columns (cumulative, mirroring Tables I/II — see docs/ARCHITECTURE.md):
  upstream      TGT-style single-loop frontend + dict map + chained store
  +frontend     multi-queue batched admission (ublk analogue), loop comm
  +comm         slot-array (Messages Array) batched comm, chained store
  +dbs          DBS replicas (the full modified engine)
  +fused        single-program engine step (core/fused.py): admission, CoW,
                mirrored stores, reads and retirement in ONE compiled
                program per batch — no host hop between admission and
                completion
  +sharded      EnginePool (core/sharded.py): S engine shards served by ONE
                vmapped fused step per pump, volumes hashed across shards,
                pipelined (double-buffered) completion
  +ring         SQ/CQ ring protocol (core/ring.py): opcode-tagged SQE path
                carrying data AND control ops through the same sharded
                step, CQ completion records on device

Rows (layer cuts): frontend-only (null backend) / without-storage (null
storage) / full engine.

``run_mixed_control`` measures the workload the ring exists for: a data
stream with ~5% snapshot/unmap control ops. ``+ring`` executes them
in-band; the ``fence`` baseline is the pre-ring engine (``+fused``), which
must drain the pipeline and dispatch each control op host-side.

``run_blockdev`` drives the public byte-addressed API
(``blockdev.VolumeManager``) — block-aligned spans plus a mixed-size
workload with ~10% unaligned writes (in-API read-modify-write) — and pins
aligned-span throughput to >= 0.9x the raw request-level ``+ring`` stream.

``run_replication`` is the replica-transport/policy matrix (ISSUE 5): the
slots engine over LocalTransport (gated >= 0.9x the identical ``+dbs``
column — the transport boundary must be free) and over a simulated network
with a straggler link, comparing write policies all/quorum/async and the
latency-weighted read policy — the quorum-vs-all tradeoff the paper
measures over a real network.

``run_serve`` is the serving pair (ISSUE 8): zero-copy KV-on-volumes
serving (``serving/engine.py`` with ``kv_backend="fused"`` — the extent
pool IS the KV cache) against the copy-based host baseline
(``kv_backend="host"``), reporting sessions/s, per-token wall P99 and the
engine step clock, plus the fork probe timing ``ServeEngine.fork`` at a
short vs a long context (``check_serve_gate`` pins zero-copy >= 1.0x
copy-based and the fork cost flat — O(1) in context length).

Also a CLI (the CI bench-smoke job, installed as ``repro-bench``):
``repro-bench --smoke --out BENCH.json --check`` runs a tiny-geometry
ladder + the mixed data+control workload + the VolumeManager blockdev
workload, writes the JSON artifact, and exits non-zero if
``+fused``/``+sharded``/``+ring`` fall below the device-resident ``+dbs``
baseline on any row, if ``+ring`` falls below ``+fused`` on the pure-data
rows, if in-band control loses to the fence-per-control-op baseline, or if
the byte API falls below 0.9x raw ``+ring`` on aligned spans
(see ``check_no_regression`` for why upstream is not the CPU-smoke floor).
``--only serve`` (or any comma-named section subset) runs just those
sections and their gates — the CI ``serve-smoke`` step.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, EngineConfig, Request, UpstreamEngine
from repro.core.blockdev import VolumeManager

COLUMNS = ("upstream", "+frontend", "+comm", "+dbs", "+fused", "+sharded",
           "+ring")
ROWS = ("frontend_only", "without_storage", "full_engine")


def make_engine(column: str, row: str, *, payload_shape=(64,),
                n_replicas: int = 2, page_blocks: int = 32,
                n_extents: int = 4096, max_pages: int = 1024,
                n_shards: int = 4, kernel: str = "auto"):
    null_backend = row == "frontend_only"
    null_storage = row == "without_storage"
    kw = dict(payload_shape=payload_shape, n_replicas=n_replicas,
              page_blocks=page_blocks, n_extents=n_extents,
              max_pages=max_pages, null_backend=null_backend,
              null_storage=null_storage, kernel=kernel)
    if column == "upstream":
        return UpstreamEngine(EngineConfig(**kw))
    if column == "+frontend":
        return Engine(EngineConfig(storage="chained", comm="loop", **kw))
    if column == "+comm":
        return Engine(EngineConfig(storage="chained", comm="slots", **kw))
    if column == "+dbs":
        return Engine(EngineConfig(storage="dbs", comm="slots", **kw))
    if column == "+fused":
        return Engine(EngineConfig(storage="dbs", comm="fused", **kw))
    if column == "+sharded":
        return Engine(EngineConfig(storage="dbs", comm="sharded",
                                   n_shards=n_shards, **kw))
    if column == "+ring":
        return Engine(EngineConfig(storage="dbs", comm="ring",
                                   n_shards=n_shards, **kw))
    raise ValueError(column)


def measure_engine(eng, *, n_requests: int, kind: str, pages: int,
                   n_volumes: int, payload: jnp.ndarray,
                   warmup: bool = True) -> float:
    """One timed steady-state drain -> ops/s. The single measurement
    protocol shared by the ladder columns and table3's shard sweep.

    ``warmup`` drains one full write batch and one read batch before the
    timed run so every batch-geometry program (including the read-only
    step variant) compiles outside the clock — the paper's fio numbers are
    steady-state too. The workload spreads requests round-robin over
    ``n_volumes`` volumes (a multi-tenant stream; on a sharded engine the
    volumes additionally hash across shards)."""
    vols = [eng.create_volume() for _ in range(n_volumes)]
    rng = np.random.default_rng(0)
    page_seq = rng.integers(0, pages, size=n_requests)
    if warmup:
        cap = getattr(eng.cfg, "batch", 64)
        for i in range(cap):
            eng.submit(Request(req_id=i, kind="write",
                               volume=vols[i % n_volumes],
                               page=i % pages, block=i % 8, payload=payload))
        for i in range(cap):
            eng.submit(Request(req_id=cap + i, kind="read",
                               volume=vols[i % n_volumes],
                               page=i % pages, block=i % 8))
        eng.drain()
        # an interleaved batch too: the ring engine compiles one program per
        # opcode-class signature, and a mixed read+write batch is its own
        for i in range(cap):
            eng.submit(Request(req_id=2 * cap + i,
                               kind="write" if i % 2 else "read",
                               volume=vols[i % n_volumes],
                               page=i % pages, block=i % 8, payload=payload))
        eng.drain()
        eng.completed = 0
    for i in range(n_requests):
        k = ("write" if (kind == "write" or (kind == "mixed" and i % 2))
             else "read")
        eng.submit(Request(req_id=i, kind=k, volume=vols[i % n_volumes],
                           page=int(page_seq[i]), block=i % 8,
                           payload=payload))
    t0 = time.perf_counter()
    done = eng.drain()
    dt = time.perf_counter() - t0
    assert done == n_requests, (done, n_requests)
    return n_requests / dt


def run_ladder(*, n_requests: int = 512, payload_elems: int = 64,
               kind: str = "mixed", pages: int = 256,
               repeats: int = 1, warmup: bool = True,
               n_volumes: int = 4, n_shards: int = 4
               ) -> Dict[str, Dict[str, float]]:
    """Returns best-of-``repeats`` ops/sec for every (column, row) cell
    (see ``measure_engine`` for the per-cell protocol)."""
    payload = jnp.ones((payload_elems,), jnp.float32)
    out: Dict[str, Dict[str, float]] = {}
    for col in COLUMNS:
        out[col] = {}
        for row in ROWS:
            out[col][row] = max(
                measure_engine(
                    make_engine(col, row, payload_shape=(payload_elems,),
                                max_pages=pages, n_shards=n_shards),
                    n_requests=n_requests, kind=kind, pages=pages,
                    n_volumes=n_volumes, payload=payload, warmup=warmup)
                for _ in range(repeats))
    return out


def _control_stream(n_requests: int, ctrl_every: int, pages: int,
                    n_volumes: int):
    """Deterministic mixed data+control op stream (~1/ctrl_every control
    ops, alternating snapshot/unmap — the paper's snapshot-heavy tenant)."""
    ops = []
    snap = True
    for i in range(n_requests):
        v = i % n_volumes
        if ctrl_every and i % ctrl_every == ctrl_every - 1:
            ops.append(("snapshot" if snap else "unmap", v, (i * 7) % pages))
            snap = not snap
        elif i % 2:
            ops.append(("write", v, i % pages))
        else:
            ops.append(("read", v, (i // 2) % pages))
    return ops


def run_mixed_control(*, n_requests: int = 512, ctrl_every: int = 20,
                      payload_elems: int = 64, pages: int = 256,
                      n_volumes: int = 4, repeats: int = 1,
                      **_ignored) -> Dict[str, float]:
    """The workload the ring protocol exists for: ~5% in-band control ops.

    ``+ring`` submits snapshot/unmap as opcode-tagged requests into the
    same stream as the data ops — they execute inside the jitted step,
    interleaved with foreground traffic. ``fence`` is the pre-ring
    behaviour: the ``+fused`` engine must drain (fence) the pipeline at
    every control op and dispatch it host-side. Both run one engine shard
    (the fused fence baseline has no shard axis) so the comparison isolates
    the protocol change. Returns best-of-``repeats`` ops/s per mode
    (control ops count as ops — both modes complete the identical op
    sequence)."""
    payload = jnp.ones((payload_elems,), jnp.float32)
    ops = _control_stream(n_requests, ctrl_every, pages, n_volumes)

    def measure(mode: str) -> float:
        eng = make_engine("+ring" if mode == "+ring" else "+fused",
                          "full_engine", payload_shape=(payload_elems,),
                          max_pages=pages, n_shards=1)
        vols = [eng.create_volume() for _ in range(n_volumes)]
        cap = getattr(eng.cfg, "batch", 64)
        for i in range(cap):                  # warm every program variant
            eng.submit(Request(req_id=i, kind="write" if i % 2 else "read",
                               volume=vols[i % n_volumes], page=i % pages,
                               block=i % 8, payload=payload))
        if mode == "+ring":
            eng.submit(Request(req_id=cap, kind="snapshot", volume=vols[0]))
            eng.submit(Request(req_id=cap + 1, kind="unmap",
                               volume=vols[0], page=0))
        else:
            eng.snapshot(vols[0])
            eng.unmap(vols[0], [0])
        eng.drain()
        eng.completed = 0
        t0 = time.perf_counter()
        if mode == "+ring":                   # in-band: one stream, one drain
            for i, (kind, v, page) in enumerate(ops):
                eng.submit(Request(
                    req_id=i, kind=kind, volume=vols[v], page=page,
                    block=i % 8, payload=payload if kind == "write" else None))
            done = eng.drain()
        else:                                 # fence per control op
            done = 0
            for i, (kind, v, page) in enumerate(ops):
                if kind in ("snapshot", "unmap"):
                    done += eng.drain()       # flush everything in flight
                    if kind == "snapshot":
                        eng.snapshot(vols[v])
                    else:
                        eng.unmap(vols[v], [page])
                    done += 1
                else:
                    eng.submit(Request(req_id=i, kind=kind, volume=vols[v],
                                       page=page, block=i % 8,
                                       payload=(payload if kind == "write"
                                                else None)))
            done += eng.drain()
        dt = time.perf_counter() - t0
        assert done == n_requests, (mode, done, n_requests)
        return n_requests / dt

    return {mode: max(measure(mode) for _ in range(repeats))
            for mode in ("+ring", "fence")}


def run_blockdev(*, n_requests: int = 512, payload_elems: int = 64,
                 pages: int = 256, n_volumes: int = 4, n_shards: int = 4,
                 repeats: int = 1, unaligned_every: int = 10,
                 **_ignored) -> Dict[str, float]:
    """The public-API workload: byte-addressed mixed-size I/O through
    ``VolumeManager`` (core/blockdev.py) on the ring backend.

    Three numbers, best-of-``repeats`` each, in BLOCK ops/s (one block = one
    SQE, so the aligned/raw numbers are the same unit as the ladder's):

    - ``aligned``  — page-aligned page-sized byte spans through the API
      ("aligned spans map straight onto batched page ops"): ONE
      ``pwrite``/``pread`` fans out to ``page_blocks`` SQEs that ride the
      engine's normal admission batches and complete on the pump's single
      CQ fetch,
    - ``mixed``    — mixed sizes (1 block / 4 blocks / 1 page) with
      ~1/``unaligned_every`` *unaligned* writes exercising the in-API
      read-modify-write path (user ops/s — an op may fan out to many SQEs),
    - ``raw_ring`` — the SAME SQE stream hand-rolled on request-level
      ``Engine`` submission, with equivalent end-to-end byte handling
      (payload encode on writes, payload decode on reads). This is the raw
      ``+ring`` reference the CI gate compares against: the API must keep
      aligned-span throughput >= 0.9x of it (``check_blockdev_gate``).
    """
    bb = payload_elems
    page_blocks = 32
    # enough page-span calls that one measurement outlasts shared-runner
    # scheduling spikes (each call is page_blocks SQEs)
    n_pages_ops = max(48, n_requests // page_blocks)  # API calls (page spans)
    n_blocks = n_pages_ops * page_blocks              # SQEs either way
    seq = [(i % n_volumes, (i // n_volumes) % (pages - 1))
           for i in range(n_pages_ops)]

    def aligned_round(api: bool):
        """Build a warmed manager and return one timed round as a thunk, so
        the api/raw rounds can be INTERLEAVED — a shared-runner scheduling
        spike then degrades both sides, not just one."""
        mgr = VolumeManager(backend="ring", n_shards=n_shards,
                            payload_elems=payload_elems, max_pages=pages,
                            n_extents=4096, max_volumes=16)
        vols = [mgr.create() for _ in range(n_volumes)]
        eng = mgr.engine
        page_bytes = mgr.page_bytes
        data = (bytes(range(256)) * ((page_bytes + 255) // 256))[:page_bytes]
        # warmup: compile every program this traffic shape needs
        for v in vols:
            v.write((pages - 1) * page_bytes, data)
            v.read((pages - 1) * page_bytes, page_bytes)
        mgr.flush()

        def one_round() -> float:
            eng.completed = 0
            t0 = time.perf_counter()
            if api:
                futs = []
                for i, (vi, p) in enumerate(seq):
                    if i % 2:
                        futs.append(vols[vi].pwrite(p * page_bytes, data))
                    else:
                        futs.append(vols[vi].pread(p * page_bytes,
                                                   page_bytes))
                mgr.flush()
                for f in futs:
                    f.result()                  # decode read payloads too
            else:
                reqs = []
                rid = 0
                for i, (vi, p) in enumerate(seq):
                    for blk in range(page_blocks):
                        kind = "write" if i % 2 else "read"
                        payload = (np.frombuffer(
                            data[blk * bb:(blk + 1) * bb], np.uint8)
                            .astype(np.float32) if i % 2 else None)
                        r = Request(req_id=rid, kind=kind,
                                    volume=vols[vi].vid, page=p, block=blk,
                                    payload=payload)
                        rid += 1
                        eng.submit(r)
                        reqs.append(r)
                eng.drain()
                for r in reqs:                  # equivalent byte decode
                    if r.kind == "read" and r.result is not None:
                        np.asarray(r.result).astype(np.uint8).tobytes()
            dt = time.perf_counter() - t0
            assert eng.completed >= n_blocks
            return n_blocks / dt
        return one_round

    def measure_mixed() -> float:
        mgr = VolumeManager(backend="ring", n_shards=n_shards,
                            payload_elems=payload_elems, max_pages=pages,
                            n_extents=4096, max_volumes=16)
        vols = [mgr.create() for _ in range(n_volumes)]
        page_bytes = mgr.page_bytes
        sizes = (bb, 4 * bb, page_bytes)
        for v in vols:                          # warm all program shapes
            v.write(0, b"w" * page_bytes)
            v.read(0, page_bytes)
            v.write(1, b"u" * bb)               # unaligned RMW shape
        mgr.flush()
        mgr.engine.completed = 0
        t0 = time.perf_counter()
        futs = []
        for i in range(n_requests):
            v = vols[i % n_volumes]
            size = sizes[i % len(sizes)]
            off = ((i // n_volumes) * page_bytes) % (mgr.capacity - 2 * size)
            if unaligned_every and i % unaligned_every == unaligned_every - 1:
                futs.append(v.pwrite(off + 3, b"u" * bb))   # unaligned RMW
            elif i % 2:
                futs.append(v.pwrite(off, b"m" * size))
            else:
                futs.append(v.pread(off, size))
        mgr.flush()
        for f in futs:
            f.result()
        dt = time.perf_counter() - t0
        return n_requests / dt

    api_round, raw_round = aligned_round(True), aligned_round(False)
    aligned = raw = 0.0
    for _ in range(max(repeats, 5)):            # interleaved best-of
        aligned = max(aligned, api_round())
        raw = max(raw, raw_round())
    return {"aligned": aligned, "raw_ring": raw,
            "mixed": max(measure_mixed() for _ in range(repeats))}


def run_replication(*, n_requests: int = 512, payload_elems: int = 64,
                    pages: int = 256, n_volumes: int = 4, repeats: int = 1,
                    straggler: int = 6, kind: str = "mixed", **_ignored
                    ) -> Dict[str, Dict[str, float]]:
    """The replica-transport/policy matrix (ISSUE 5): the host-dispatch
    (+dbs, ``comm="slots"``) engine over each controller<->replica
    transport and write/read policy (core/transport.py,
    core/replication.py). Best-of-``repeats`` ops/s per cell.

    - ``local/all`` — the redesigned default: LocalTransport,
      write-to-all. Measured on BOTH pure-data rows with the ladder's
      default 2 replicas so it is the exact configuration of the ``+dbs``
      column — the CI gate pins it to >= 0.9x that column
      (``check_replication_gate``): the transport boundary is allowed a
      message object, not a slow path.
    - ``simnet/*`` — the policy matrix the paper measures over a real
      network, on a simulated one: 3 replicas, one ``straggler``x-slower
      link (``latency=[1, 1, straggler]``). ``all`` waits for the
      straggler every batch; ``quorum`` acks on the two fast links (the
      straggler catches up via per-link FIFO, bounded by the in-flight
      window); ``async`` is write-behind; ``quorum+latreads`` adds the
      latency-weighted read policy so reads also avoid the slow link —
      the quorum-vs-all tradeoff, benchmarkable.
    """
    payload = jnp.ones((payload_elems,), jnp.float32)
    simnet = dict(transport="simnet",
                  transport_opts=dict(latency=[1, 1, straggler], window=8))
    scenarios = {
        "local/all": dict(n_replicas=2),
        "simnet/all": dict(n_replicas=3, write_policy="all", **simnet),
        "simnet/quorum": dict(n_replicas=3, write_policy="quorum", **simnet),
        "simnet/async": dict(n_replicas=3, write_policy="async", **simnet),
        "simnet/quorum+latreads": dict(n_replicas=3, write_policy="quorum",
                                       read_policy="latency", **simnet),
    }
    out: Dict[str, Dict[str, float]] = {}
    for name, kw in scenarios.items():
        rows = (("full_engine", "without_storage") if name == "local/all"
                else ("full_engine",))
        out[name] = {}

        def make(row: str):
            # geometry mirrors make_engine's, so the local/all cells are
            # the exact configuration of the +dbs column the gate compares
            # against (and the same --kind workload drives both)
            return Engine(EngineConfig(
                storage="dbs", comm="slots", n_extents=4096,
                payload_shape=(payload_elems,), max_pages=pages,
                null_storage=row == "without_storage", **kw))

        for row in rows:
            out[name][row] = max(
                measure_engine(make(row), n_requests=n_requests, kind=kind,
                               pages=pages, n_volumes=n_volumes,
                               payload=payload)
                for _ in range(repeats))
        # the metric the policies actually trade: controller-observed wait
        # time in SIMULATED ticks per op (deterministic — no repeats).
        # Wall ops/s barely separates the policies because ticking a
        # simulated link costs the host ~nothing; a real network charges
        # the latency the tick count stands in for.
        eng = make("full_engine")
        measure_engine(eng, n_requests=n_requests, kind=kind, pages=pages,
                       n_volumes=n_volumes, payload=payload, warmup=False)
        out[name]["wait_ticks_per_op"] = (eng.backend.wait_ticks
                                          / n_requests)
    return out


def run_trace(*, smoke: bool = False, trace_seed: int = 0,
              chaos_seed: int = 0, **_ignored) -> Dict[str, Any]:
    """The chaos-harness scenario matrix (ISSUE 6): trace-driven load with
    byte-oracle checking over the named ``repro.harness.SCENARIOS`` catalog,
    plus the replay-determinism double run. Returns the BENCH ``trace``
    document; ``check_trace_gates`` (re-exported from the harness) gates
    it under ``--check``."""
    from repro.harness import run_matrix
    return run_matrix(smoke=smoke, trace_seed=trace_seed,
                      chaos_seed=chaos_seed)


def check_trace_gates(trace: Dict[str, Any]) -> List[str]:
    from repro.harness import check_trace_gates as _gates
    return _gates(trace)


def run_kernels(*, repeats: int = 3, **_ignored) -> Dict[str, Any]:
    """The per-DBS-kernel micro benchmark (ISSUE 7): for every REGISTERED
    kernel (kernels/dbs registry), wall time + nominal achieved bytes/s for
    the write and read data planes of one engine-shaped batch (CoW lanes,
    a duplicate-dst write group, failed lanes, read holes), a bit-identity
    check against the ``xla`` reference, and — on compiled backends only —
    the ``+fused`` full_engine row rerun with ``kernel="pallas"`` vs
    ``kernel="xla"`` (the perf half of ``check_kernel_gate``;
    interpret-mode Pallas wall times measure the interpreter, not the
    kernel, so that ratio is only taken where the kernel compiles).
    Lands in BENCH json under ``kernels``; ``benchmarks/roofline.py``
    renders achieved-vs-peak bytes/s from it."""
    from repro.core import dbs
    from repro.kernels.dbs import (dbs_read_bytes, dbs_write_bytes,
                                   make_kernel)
    from repro.kernels.dbs.registry import available_kernels
    from repro.utils.machine import machine_profile

    prof = machine_profile()
    e, page, d, b = 129, 8, 32, 32          # +1 reserved scratch row
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    pool = jax.random.normal(ks[0], (e, page, d))
    payload = jax.random.normal(ks[1], (b, d))
    lane = jnp.arange(b, dtype=jnp.int32)
    blocks = (lane * 3) % page
    # duplicate-dst groups: lane 8k+5 joins lane 8k+4's extent (the leader,
    # which also CoWs — cow_src sits on the group's first live lane, the
    # write_pages convention the kernels' routing assumes)
    dst = jnp.where(lane % 8 == 5, lane - 1, lane) * 3 % (e - 1)
    cow_src = jnp.where(lane % 8 == 4, (dst + 61) % (e - 1), -1)
    cow_src = cow_src.astype(jnp.int32)
    ok = lane % 11 != 10
    ext = jnp.where(lane % 5 == 0, -1, dst).astype(jnp.int32)  # read holes
    itemsize = pool.dtype.itemsize
    wbytes = dbs_write_bytes(int(ok.sum()),
                             int(((cow_src >= 0) & ok).sum()),
                             page, d, itemsize)
    rbytes = dbs_read_bytes(b, d, itemsize)

    def _time(fn, *args):
        fn(*args).block_until_ready()       # compile outside the clock
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn(*args).block_until_ready()
        return (time.perf_counter() - t0) / repeats * 1e6

    xla = make_kernel("xla")
    ref_w = xla.write(pool, dbs.WriteOps(dst=dst, cow_src=cow_src, ok=ok),
                      payload, blocks)
    ref_r = xla.read(pool, ext, blocks)
    out: Dict[str, Any] = {"profile": prof.to_dict()}
    for name in available_kernels():
        kern = make_kernel(name)
        wf = jax.jit(lambda p, pay, dd, cc, oo, bl, k=kern: k.write(
            p, dbs.WriteOps(dst=dd, cow_src=cc, ok=oo), pay, bl))
        rf = jax.jit(lambda p, ee, bl, k=kern: k.read(p, ee, bl))
        got_w = wf(pool, payload, dst, cow_src, ok, blocks)
        got_r = rf(pool, ext, blocks)
        identical = bool(
            np.array_equal(np.asarray(got_w[:e - 1]),      # excl. dump row
                           np.asarray(ref_w[:e - 1]))
            and np.array_equal(np.asarray(got_r), np.asarray(ref_r)))
        w_us = _time(wf, pool, payload, dst, cow_src, ok, blocks)
        r_us = _time(rf, pool, ext, blocks)
        out[name] = {
            "write_us": w_us, "read_us": r_us,
            "write_bytes_per_s": wbytes / (w_us * 1e-6),
            "read_bytes_per_s": rbytes / (r_us * 1e-6),
            "write_vs_peak": wbytes / (w_us * 1e-6) / prof.hbm_bw,
            "read_vs_peak": rbytes / (r_us * 1e-6) / prof.hbm_bw,
            "identical": identical,
        }
    if jax.default_backend() == "tpu":      # the compiled-only perf ratio
        pay = jnp.ones((16,), jnp.float32)
        for kname in ("pallas", "xla"):
            eng = make_engine("+fused", "full_engine", payload_shape=(16,),
                              max_pages=128, n_extents=512, kernel=kname)
            out[f"fused_{kname}_ops_s"] = measure_engine(
                eng, n_requests=512, kind="mixed", pages=64, n_volumes=4,
                payload=pay)
    return out


def check_kernel_gate(kernels: Dict[str, Any],
                      floor: float = 0.9) -> List[str]:
    """The all-Pallas-hot-path gate (ISSUE 7 acceptance): every registered
    DBS kernel must be bit-identical to the ``xla`` reference on the
    crafted engine batch, and on compiled backends the ``+fused`` row with
    ``kernel="pallas"`` must hold >= ``floor``x the ``kernel="xla"`` run —
    kernel ownership buys lowering quality, not overhead."""
    problems = []
    for name, row in kernels.items():
        if isinstance(row, dict) and "identical" in row \
                and not row["identical"]:
            problems.append(
                f"kernel {name}: NOT bit-identical to the xla reference")
    if "fused_pallas_ops_s" in kernels:
        p, x = kernels["fused_pallas_ops_s"], kernels["fused_xla_ops_s"]
        if p < x * floor:
            problems.append(
                f"kernel pallas: +fused {p:.0f} ops/s < {floor:g}x "
                f"xla ({x:.0f} ops/s)")
    return problems


def check_replication_gate(repl: Dict[str, Dict[str, float]],
                           ladder: Dict[str, Dict[str, float]],
                           floor: float = 0.9) -> List[str]:
    """The transport-redesign gate (ISSUE 5 acceptance): ``local/all`` —
    the redesigned replica path — must hold >= ``floor``x the ``+dbs``
    column (the identical engine configuration) on the pure-data rows. The
    boundary buys pluggability, not overhead."""
    problems = []
    for row in ("full_engine", "without_storage"):
        ops, base = repl["local/all"][row], ladder["+dbs"][row]
        if ops < base * floor:
            problems.append(
                f"replication local/all/{row}: {ops:.0f} ops/s < {floor:g}x "
                f"+dbs ({base:.0f} ops/s)")
    return problems


def check_blockdev_gate(blockdev: Dict[str, float],
                        floor: float = 0.9) -> List[str]:
    """The public-API gate (ISSUE 4 acceptance): byte-addressed aligned
    spans through ``VolumeManager`` must hold >= ``floor``x the raw
    request-level ``+ring`` throughput on the identical op stream — the
    ublk-style surface is allowed geometry translation, not host hops."""
    if blockdev["aligned"] < blockdev["raw_ring"] * floor:
        return [f"blockdev: aligned {blockdev['aligned']:.0f} ops/s < "
                f"{floor:g}x raw +ring ({blockdev['raw_ring']:.0f} ops/s)"]
    return []


def snapshot_degradation(*, n_snapshots=(0, 4, 16, 64), n_reads: int = 256,
                         pages: int = 64) -> Dict[str, List[dict]]:
    """Reads vs snapshot count. Two metrics per point:

    - ops/s (wall time; at CPU scale dict walks are ~ns, so this mostly
      shows engine overheads),
    - **layers touched per read** — the structural cost the paper describes
      ("reads may have to go through the whole chain"): grows linearly for
      the chained sparse-file-style store, constant 1 for DBS's flattened
      in-memory extent map.
    All data is written *before* the first snapshot, so chained reads must
    walk to the bottom of the chain — the paper's worst case.
    """
    res: Dict[str, List[dict]] = {"chained": [], "dbs": []}
    payload = jnp.ones((16,), jnp.float32)
    rng = np.random.default_rng(0)
    for col, key in (("+comm", "chained"), ("+dbs", "dbs")):
        for ns in n_snapshots:
            eng = make_engine(col, "full_engine", payload_shape=(16,),
                              max_pages=pages, n_extents=pages * (ns + 2) + 64)
            vol = eng.create_volume()
            for p in range(pages):              # base data in the oldest layer
                eng.submit(Request(req_id=p, kind="write", volume=vol,
                                   page=p, block=0, payload=payload))
            eng.drain()
            for s in range(ns):                 # empty-ish newer layers
                eng.snapshot(vol)
                eng.submit(Request(req_id=0, kind="write", volume=vol,
                                   page=0, block=0, payload=payload))
                eng.drain()
            for i in range(n_reads):
                eng.submit(Request(req_id=i, kind="read", volume=vol,
                                   page=int(rng.integers(1, pages)), block=0))
            t0 = time.perf_counter()
            done = eng.drain()
            dt = time.perf_counter() - t0
            if key == "chained":
                store = eng.backend.stores[0]
                walked = sum(s.layers_walked for s in eng.backend.stores)
                nreads = sum(s.reads for s in eng.backend.stores)
                depth = walked / max(nreads, 1)
            else:
                depth = 1.0                     # one table gather, always
            res[key].append({"snapshots": ns, "ops_per_s": done / dt,
                             "layers_per_read": depth})
    return res


def run_serve(*, smoke: bool = False, n_sessions: int = 16, max_new: int = 8,
              repeats: int = 2, **_ignored) -> Dict[str, Any]:
    """Serving throughput (PR 8): zero-copy KV-on-volumes
    (``kv_backend="fused"`` — extent pool IS the cache, one fused decode
    program) vs the copy-based baseline (``kv_backend="host"`` — model-owned
    pools, per-layer ``dbs_copy`` CoW, unfused step).

    Two clocks per backend: wall-clock (sessions/s, per-token P99 seconds)
    and the engine step clock (per-session steps to completion) — both
    through ``harness.stats.summarize``. Plus the fork-O(1) probe: the cost
    of ``ServeEngine.fork`` at a short vs a long context must be flat
    (``check_serve_gate``). Returns the BENCH ``serve`` document."""
    from repro.configs import smoke_config
    from repro.harness.stats import summarize
    from repro.models import init_params
    from repro.serving import GenRequest, ServeEngine

    cfg = smoke_config("granite-3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    if smoke:
        n_sessions, max_new = min(n_sessions, 10), min(max_new, 6)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(6 + (i % 5),))
               for i in range(n_sessions)]

    def _measure(kv_backend: str) -> Dict[str, Any]:
        best = None
        for _ in range(max(repeats, 1)):
            eng = ServeEngine(cfg, params, n_slots=8, max_len=64,
                              kv_backend=kv_backend)
            # warm the engine's compile caches outside the timed window
            eng.submit(GenRequest(req_id=10 ** 6, prompt=prompts[0].copy(),
                                  max_new=2))
            eng.run(max_steps=8)
            t0 = time.perf_counter()
            for rid in range(n_sessions):
                eng.submit(GenRequest(req_id=rid, prompt=prompts[rid].copy(),
                                      max_new=max_new))
            token_wall: List[float] = []
            done_steps: Dict[int, int] = {}
            for _step in range(64 * n_sessions):
                ts = time.perf_counter()
                out = eng.step()
                dt = time.perf_counter() - ts
                token_wall.extend(dt for _ in out)
                for rid, _tok in out:
                    if eng.live[rid].done and rid not in done_steps:
                        done_steps[rid] = eng._steps
                if len(done_steps) == n_sessions:
                    break
            total = time.perf_counter() - t0
            doc = {"sessions_per_s": n_sessions / total,
                   "tokens_per_s": len(token_wall) / total,
                   "token_wall_s": summarize(token_wall),
                   "session_steps": summarize(list(done_steps.values()))}
            if best is None or doc["sessions_per_s"] > best["sessions_per_s"]:
                best = doc
        return best

    def _fork_cost(ctx_len: int, k: int = 5) -> float:
        eng = ServeEngine(cfg, params, n_slots=4, max_len=128,
                          kv_backend="fused")
        eng.submit(GenRequest(req_id=0,
                              prompt=rng.integers(0, cfg.vocab_size,
                                                  size=(ctx_len,)),
                              max_new=64))
        eng.step()
        times = []
        for i in range(k):
            t0 = time.perf_counter()
            child = eng.fork(0, 100 + i, max_new=1)
            times.append(time.perf_counter() - t0)
            eng._finish(child)
        return min(times)

    short_ctx, long_ctx = 8, 96
    cost_short = _fork_cost(short_ctx)
    cost_long = _fork_cost(long_ctx)
    return {"n_sessions": n_sessions, "max_new": max_new,
            "zero_copy": _measure("fused"),
            "copy_based": _measure("host"),
            "fork": {"short_ctx": short_ctx, "long_ctx": long_ctx,
                     "cost_short_s": cost_short, "cost_long_s": cost_long,
                     "ctx_ratio": long_ctx / short_ctx,
                     "cost_ratio": cost_long / max(cost_short, 1e-9)}}


def _np_checksum(buf: bytes, page_bytes: int) -> int:
    """Vectorized-numpy host checksum over read-back bytes — the strongest
    practical read-back-and-compute baseline (same spec as the in-band
    ``checksum`` storage function; repro/compute/functions.py)."""
    a = (np.frombuffer(buf, np.uint8).astype(np.uint32)
         .reshape(-1, page_bytes) + np.uint32(1))
    j = np.arange(page_bytes, dtype=np.uint32) % np.uint32(31)
    rot = (a << j) | (a >> ((np.uint32(32) - j) % np.uint32(32)))
    psums = np.bitwise_xor.reduce(rot, axis=1)
    p = np.arange(psums.shape[0], dtype=np.uint32) % np.uint32(31)
    rot2 = (psums << p) | (psums >> ((np.uint32(32) - p) % np.uint32(32)))
    total = int(np.bitwise_xor.reduce(rot2))
    return total - (1 << 32) if total >= (1 << 31) else total


def run_compute(*, payload_elems: int = 64, pages: int = 256,
                n_volumes: int = 4, n_shards: int = 4, repeats: int = 1,
                **_ignored) -> Dict[str, Any]:
    """Computational storage (ISSUE 9): the in-band volume scan — ONE
    ``COMPUTE`` SQE running the ``checksum`` storage function inside the
    ring step — against the read-back baseline: ``pread`` the full volume
    through the same API (full SQE fan-out) and checksum the bytes on the
    host with vectorized numpy. Both sides run on the SAME manager and
    data, interleaved best-of-``repeats``; both results are checked
    bit-identical to the registry entry's pure-Python mirror. Lands in
    BENCH json under ``compute``; ``check_compute_gate`` pins in-band to
    >= 2x read-back and bit-identity."""
    from repro.compute import make_storage_fn

    nv = min(n_volumes, 4)                  # full-capacity reads are the
    mgr = VolumeManager(backend="ring", n_shards=n_shards,  # baseline cost
                        payload_elems=payload_elems, max_pages=pages,
                        n_extents=4 * pages * nv, max_volumes=16)
    vols = [mgr.create() for _ in range(nv)]
    cap, pby = mgr.capacity, mgr.page_bytes
    blobs = {}
    for k, v in enumerate(vols):
        blobs[v.vid] = bytes((k * 37 + i * 11) % 251 for i in range(cap))
        v.write(0, blobs[v.vid])
    mgr.flush()
    entry = make_storage_fn("checksum")
    expected = {v.vid: entry.mirror(bytearray(blobs[v.vid]), pby,
                                    mgr.block_bytes, 0, cap // pby, 0,
                                    None)[0]
                for v in vols}

    def in_band_round():
        t0 = time.perf_counter()
        futs = [(v.vid, v.compute("checksum")) for v in vols]
        mgr.flush()
        vals = {vid: f.result().value for vid, f in futs}
        return time.perf_counter() - t0, vals

    def read_back_round():
        t0 = time.perf_counter()
        futs = [(v.vid, v.pread(0, cap)) for v in vols]
        mgr.flush()
        vals = {vid: _np_checksum(f.result(), pby) for vid, f in futs}
        return time.perf_counter() - t0, vals

    # warm both program shapes outside the clock
    in_band_round(), read_back_round()
    identical = True
    t_in = t_back = float("inf")
    for _ in range(max(repeats, 3)):        # interleaved best-of
        dt, vals = in_band_round()
        t_in = min(t_in, dt)
        identical &= vals == expected
        dt, vals = read_back_round()
        t_back = min(t_back, dt)
        identical &= vals == expected
    scanned = nv * cap
    return {"volumes": nv, "capacity_bytes": cap,
            "in_band_scans_per_s": nv / t_in,
            "read_back_scans_per_s": nv / t_back,
            "in_band_bytes_per_s": scanned / t_in,
            "read_back_bytes_per_s": scanned / t_back,
            "speedup": t_back / t_in, "identical": identical}


def check_compute_gate(compute: Dict[str, Any],
                       floor: float = 2.0) -> List[str]:
    """The computational-storage gate (ISSUE 9 acceptance): the in-band
    volume scan must be bit-identical to the host reference AND hold
    >= ``floor``x the read-back-and-compute-on-host baseline — pushing the
    function to the data is only worth an opcode if it beats shipping the
    bytes."""
    problems = []
    if not compute["identical"]:
        problems.append("compute: in-band/read-back checksum NOT "
                        "bit-identical to the host reference mirror")
    ib, rb = compute["in_band_bytes_per_s"], compute["read_back_bytes_per_s"]
    if ib < rb * floor:
        problems.append(
            f"compute: in-band volume scan {ib:.3g} B/s < {floor:g}x "
            f"read-back baseline ({rb:.3g} B/s)")
    return problems


def run_durability(*, payload_elems: int = 64, pages: int = 64,
                   n_requests: int = 512, repeats: int = 1,
                   **_ignored) -> Dict[str, Any]:
    """Durability subsystem (ISSUE 10), three measurements on the fused
    engine:

    (a) **journal overhead** — the same aligned-block write stream with
        the write-ahead journal attached vs detached (interleaved
        best-of-``repeats``). Group commit makes the bound ONE file append
        per pump, not per op, so the attached column must hold the
        ``check_durability_gate`` floor (<= 30% overhead).
    (b) **crash recovery** — after the journaled run the manager is
        ABANDONED (never closed — a dead process) and recovered from the
        WAL; the recovered volume must read back byte-identical to the
        original (the gate's correctness half).
    (c) **spill-tier read throughput** — full-volume reads with the extent
        pool 2x over-subscribed (``tier=`` budget at half the mapped
        extents, spill/fill cycles every round) vs the all-resident pool;
        reported as bytes/s + the achieved ratio.
    """
    import shutil
    import tempfile

    from repro.durability import recover

    tmp = tempfile.mkdtemp(prefix="repro-durability-bench-")
    geo = dict(backend="fused", payload_elems=payload_elems, page_blocks=4,
               max_pages=pages, n_extents=4 * pages, max_volumes=8,
               batch=32)
    burst = 32
    payloads = [bytes((k * 31 + i) % 251 for i in range(payload_elems))
                for k in range(burst)]

    def write_stream(mgr, vid, n_blocks):
        t0 = time.perf_counter()
        for i in range(n_requests):
            mgr.pwrite(vid, ((i * 7919) % n_blocks) * payload_elems,
                       payloads[i % burst])
            if (i + 1) % burst == 0:
                mgr.flush()
        mgr.flush(durable=True)
        return time.perf_counter() - t0

    try:
        jp = f"{tmp}/wal.dbsj"
        mgr_on = VolumeManager(journal=jp, **geo)
        mgr_off = VolumeManager(**geo)
        cap = mgr_on.capacity
        n_blocks = cap // payload_elems
        vid_on = mgr_on.create().vid
        vid_off = mgr_off.create().vid
        write_stream(mgr_on, vid_on, n_blocks)      # warm both programs
        write_stream(mgr_off, vid_off, n_blocks)
        t_on = t_off = float("inf")
        for _ in range(max(repeats, 3)):            # interleaved best-of
            t_on = min(t_on, write_stream(mgr_on, vid_on, n_blocks))
            t_off = min(t_off, write_stream(mgr_off, vid_off, n_blocks))
        want = mgr_on.open(vid_on).read(0, cap)
        mgr_off.close()
        del mgr_on                                  # crash: abandoned
        mgr_rec = recover(jp, **geo)
        got = mgr_rec.open(vid_on).read(0, cap)
        rec_info = dict(mgr_rec.recovery_info)
        rec_info.pop("installed", None)
        mgr_rec.close()

        def read_tput(tier):
            kwt = dict(geo, **({} if tier is None else {"tier": tier}))
            m = VolumeManager(**kwt)
            vids = [m.create().vid for _ in range(2)]
            pby = m.page_bytes
            for v in vids:                          # map 2 x pages extents
                for p in range(pages):
                    m.pwrite(v, p * pby, payloads[p % burst] * 4)
            m.flush()
            best = float("inf")
            for _ in range(max(repeats, 3)):
                t0 = time.perf_counter()
                for v in vids:
                    m.open(v).read(0, cap)
                best = min(best, time.perf_counter() - t0)
            spills = (m.stats()["tier"]["extents_spilled"]
                      if tier is not None else 0)
            m.close()
            return 2 * cap / best, spills

        resident_bps, _ = read_tput(None)
        tiered_bps, spilled = read_tput(pages)      # budget = half the map
        return {
            "journal_on_ops_per_s": n_requests / t_on,
            "journal_off_ops_per_s": n_requests / t_off,
            "journal_overhead": t_on / t_off - 1.0,
            "recovered_identical": got == want,
            "recovery": rec_info,
            "tier_read_bytes_per_s": tiered_bps,
            "resident_read_bytes_per_s": resident_bps,
            "tier_read_ratio": tiered_bps / resident_bps,
            "tier_extents_spilled": spilled,
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def check_durability_gate(durability: Dict[str, Any],
                          floor: float = 0.77) -> List[str]:
    """ISSUE 10 acceptance: recovery is byte-identical, and the write-ahead
    journal costs at most 30% of the unjournaled write stream (group
    commit: one append per pump — a per-op fsync would fail this)."""
    problems = []
    if not durability["recovered_identical"]:
        problems.append("durability: WAL recovery is NOT byte-identical "
                        "to the crashed manager's volume")
    on = durability["journal_on_ops_per_s"]
    off = durability["journal_off_ops_per_s"]
    if on < off * floor:
        problems.append(
            f"durability: journaled writes {on:.0f} ops/s < {floor:g}x "
            f"unjournaled ({off:.0f} ops/s) — journal overhead "
            f"{durability['journal_overhead'] * 100:.0f}% exceeds "
            f"{(1 - floor) / floor * 100:.0f}%")
    if durability["tier_extents_spilled"] <= 0:
        problems.append("durability: spill-tier bench never spilled — the "
                        "2x over-subscription did not exercise the tier")
    return problems


def check_serve_gate(serve: Dict[str, Any], floor: float = 1.0,
                     fork_flat: float = 4.0) -> List[str]:
    """PR 8 acceptance: zero-copy serving holds >= ``floor``x the
    copy-based baseline's sessions/s, and fork cost stays flat in context
    length (a 12x longer context may cost at most ``fork_flat``x — noise
    margin on an O(1) operation, far below the 12x an O(context) copy
    would show)."""
    problems = []
    zc = serve["zero_copy"]["sessions_per_s"]
    cb = serve["copy_based"]["sessions_per_s"]
    if zc < cb * floor:
        problems.append(f"serve: zero-copy {zc:.2f} sessions/s < {floor:g}x "
                        f"copy-based ({cb:.2f} sessions/s)")
    fork = serve["fork"]
    if fork["cost_ratio"] > fork_flat:
        problems.append(
            f"serve: fork cost ratio {fork['cost_ratio']:.2f} at "
            f"{fork['ctx_ratio']:.0f}x context exceeds {fork_flat:g} "
            "(fork must be O(1) in context length)")
    return problems


# ---------------------------------------------------------------------------
# CLI — the CI bench-smoke job (and quick local runs)
# ---------------------------------------------------------------------------
# repeats=3 (best-of): shared CI runners inject multi-ms scheduling spikes;
# max-over-repeats recovers the machine-limited number per cell
SMOKE = dict(n_requests=512, payload_elems=16, pages=64, n_volumes=8,
             n_shards=4, repeats=3)


def check_no_regression(ladder: Dict[str, Dict[str, float]],
                        columns=("+fused", "+sharded", "+ring"),
                        baseline: str = "+dbs",
                        floor: float = 0.7) -> List[str]:
    """The fused/sharded columns must not collapse below the device-resident
    baseline column (``+dbs``, the pre-fused engine) on any row — the floor
    the CI bench job enforces per run.

    Why not the ``upstream`` column: at smoke geometry on a CPU runner the
    upstream baseline is a pure-Python dict loop with no device dispatch at
    all, so it outruns every device-resident column by construction (there
    is no real storage medium to dominate the clock, the situation the
    paper measures). Regressions in the columns this repo *adds* show up as
    losing to ``+dbs`` within one run; ``floor`` leaves margin for shared-
    runner noise (cross-run absolute numbers are meaningless there).
    """
    problems = []
    for col in columns:
        for row, ops in ladder.get(col, {}).items():
            base = ladder[baseline][row] * floor
            if ops < base:
                problems.append(
                    f"{col}/{row}: {ops:.0f} ops/s < {floor:g}x "
                    f"{baseline} ({ladder[baseline][row]:.0f} ops/s)")
    return problems


def check_ring_gates(ladder: Dict[str, Dict[str, float]],
                     mixed: Optional[Dict[str, float]] = None,
                     floor: float = 0.7) -> List[str]:
    """The ring column's two contracts (ISSUE 3 acceptance):

    - pure-data rows: ``+ring`` holds the ``+fused`` column (the SQ/CQ
      protocol must not tax the data path it generalizes),
    - the mixed data+control workload: in-band control beats the
      fence-per-control-op baseline.

    ``floor`` leaves shared-runner noise margin within one run.
    """
    problems = check_no_regression(ladder, columns=("+ring",),
                                   baseline="+fused", floor=floor)
    if mixed is not None and mixed["+ring"] < mixed["fence"] * floor:
        problems.append(
            f"mixed_control: +ring {mixed['+ring']:.0f} ops/s < {floor:g}x "
            f"fence baseline ({mixed['fence']:.0f} ops/s)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry (CI per-PR run)")
    ap.add_argument("--kind", default="mixed",
                    choices=("mixed", "read", "write"))
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write the ladder as JSON (the CI artifact)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if +fused/+sharded regress below the "
                         "+dbs baseline (see check_no_regression)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of sections to run "
                         "(ladder,mixed,blockdev,replication,trace,"
                         "kernels,serve,compute,durability); default runs "
                         "everything")
    args = ap.parse_args(argv)

    sections = ("ladder", "mixed", "blockdev", "replication", "trace",
                "kernels", "serve", "compute", "durability")
    if args.only is None:
        want = set(sections)
    else:
        want = {s.strip() for s in args.only.split(",") if s.strip()}
        unknown = want - set(sections)
        if unknown:
            ap.error(f"--only: unknown sections {sorted(unknown)}")

    kw = dict(SMOKE) if args.smoke else {}
    if args.n_requests is not None:
        kw["n_requests"] = args.n_requests
    ladder = run_ladder(kind=args.kind, **kw) if "ladder" in want else None
    mixed = run_mixed_control(**kw) if "mixed" in want else None
    blockdev = run_blockdev(**kw) if "blockdev" in want else None
    replication = (run_replication(kind=args.kind, **kw)
                   if "replication" in want else None)
    trace = run_trace(smoke=bool(args.smoke)) if "trace" in want else None
    kernels = run_kernels(**kw) if "kernels" in want else None
    serve = run_serve(smoke=bool(args.smoke), **kw) if "serve" in want else None
    compute = run_compute(**kw) if "compute" in want else None
    durability = run_durability(**kw) if "durability" in want else None

    if ladder is not None:
        width = max(len(c) for c in COLUMNS) + 2
        print("row".ljust(18) + "".join(c.rjust(width) for c in COLUMNS))
        for row in ROWS:
            cells = "".join(f"{ladder[c][row]:{width}.0f}" for c in COLUMNS)
            print(row.ljust(18) + cells + "   ops/s")
    if mixed is not None:
        print("mixed data+control (~5% snapshot/unmap): "
              f"+ring {mixed['+ring']:.0f} ops/s vs fence-per-control-op "
              f"{mixed['fence']:.0f} ops/s")
    if blockdev is not None:
        print("blockdev (byte-addressed VolumeManager, ring backend): "
              f"aligned {blockdev['aligned']:.0f} ops/s vs raw +ring "
              f"{blockdev['raw_ring']:.0f} ops/s; mixed-size ~10% unaligned "
              f"{blockdev['mixed']:.0f} ops/s")
    if replication is not None:
        repl_cells = "  ".join(
            f"{name} {rows['full_engine']:.0f}ops/s"
            f"/{rows['wait_ticks_per_op']:.2f}tk"
            for name, rows in replication.items())
        print("replication transports/policies (slots engine, full_engine, "
              "simnet straggler link; ops/s wall + controller wait "
              f"ticks/op): {repl_cells}")
    if trace is not None:
        det = trace.get("determinism", {})
        trace_cells = "  ".join(
            f"{name} ok={doc['oracle_ok']}"
            f"/p99={doc['latency']['all']['p99']:g}tk"
            for name, doc in trace.items() if name != "determinism")
        print("chaos harness (trace-driven load + fault schedule, byte "
              f"oracle; per-scenario oracle verdict + pump-tick P99): "
              f"{trace_cells}  determinism match={det.get('match')}")
    if kernels is not None:
        kern_cells = "  ".join(
            f"{name} w={row['write_bytes_per_s']:.3g}B/s "
            f"r={row['read_bytes_per_s']:.3g}B/s ok={row['identical']}"
            for name, row in kernels.items()
            if isinstance(row, dict) and "write_us" in row)
        print("dbs kernels (registry; nominal achieved bytes/s + "
              "bit-identity vs the xla reference; profile "
              f"{kernels['profile']['name']}): {kern_cells}")
    if serve is not None:
        print("serving (zero-copy KV-on-volumes vs copy-based host "
              "baseline; sessions/s + per-token wall P99): zero-copy "
              f"{serve['zero_copy']['sessions_per_s']:.2f}sess/s"
              f"/p99={serve['zero_copy']['token_wall_s']['p99']:.4f}s  "
              f"copy-based {serve['copy_based']['sessions_per_s']:.2f}sess/s"
              f"/p99={serve['copy_based']['token_wall_s']['p99']:.4f}s  "
              f"fork x{serve['fork']['ctx_ratio']:.0f}ctx cost ratio "
              f"{serve['fork']['cost_ratio']:.2f}")
    if compute is not None:
        print("computational storage (in-band checksum volume scan vs "
              "read-back + host numpy): in-band "
              f"{compute['in_band_bytes_per_s']:.3g} B/s vs read-back "
              f"{compute['read_back_bytes_per_s']:.3g} B/s "
              f"(x{compute['speedup']:.1f}); bit-identical to the mirror: "
              f"{compute['identical']}")
    if durability is not None:
        print("durability (write-ahead journal + WAL recovery + spill "
              "tier): journaled "
              f"{durability['journal_on_ops_per_s']:.0f} ops/s vs "
              f"unjournaled {durability['journal_off_ops_per_s']:.0f} "
              f"ops/s ({durability['journal_overhead'] * 100:+.0f}%); "
              "recovered byte-identical: "
              f"{durability['recovered_identical']}; tiered reads at 2x "
              f"over-subscription {durability['tier_read_bytes_per_s']:.3g}"
              f" B/s vs all-resident "
              f"{durability['resident_read_bytes_per_s']:.3g} B/s "
              f"(x{durability['tier_read_ratio']:.2f})")

    if args.out:
        doc = {"bench": "ladder", "kind": args.kind,
               "smoke": bool(args.smoke), "params": kw,
               "columns": list(COLUMNS), "rows": list(ROWS)}
        for key, val in (("ops_per_s", ladder), ("mixed_control", mixed),
                         ("blockdev", blockdev), ("replication", replication),
                         ("trace", trace), ("kernels", kernels),
                         ("serve", serve), ("compute", compute),
                         ("durability", durability)):
            if val is not None:
                doc[key] = val
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")

    if args.check:
        problems = []
        if ladder is not None:
            problems += check_no_regression(ladder)
        if ladder is not None and mixed is not None:
            problems += check_ring_gates(ladder, mixed)
        if blockdev is not None:
            problems += check_blockdev_gate(blockdev)
        if replication is not None and ladder is not None:
            problems += check_replication_gate(replication, ladder)
        if trace is not None:
            problems += check_trace_gates(trace)
        if kernels is not None:
            problems += check_kernel_gate(kernels)
        if serve is not None:
            problems += check_serve_gate(serve)
        if compute is not None:
            problems += check_compute_gate(compute)
        if durability is not None:
            problems += check_durability_gate(durability)
        if problems:
            print("REGRESSION:\n  " + "\n  ".join(problems), file=sys.stderr)
            return 1
        print("check OK: +fused/+sharded/+ring hold the +dbs floor on every "
              "row, +ring holds +fused on pure data and beats the fence on "
              "mixed data+control, the VolumeManager byte API holds "
              "0.9x raw +ring on aligned spans, the replica-transport "
              "local/all path holds 0.9x the +dbs column on pure data, "
              "the chaos harness is oracle-clean, replay-deterministic and "
              "inside its straggler tail bounds, every registered DBS "
              "kernel is bit-identical to the xla reference, zero-copy "
              "serving holds the copy-based floor with O(1) fork, the "
              "in-band volume scan is bit-identical to the host reference "
              "at >= 2x the read-back baseline, and the write-ahead "
              "journal holds its overhead bound with byte-identical WAL "
              "recovery (sections gated by --only run their checks only)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
