"""The paper's §IV-A top-down methodology as a benchmark harness.

Columns (cumulative, mirroring Tables I/II — see docs/ARCHITECTURE.md):
  upstream      TGT-style single-loop frontend + dict map + chained store
  +frontend     multi-queue batched admission (ublk analogue), loop comm
  +comm         slot-array (Messages Array) batched comm, chained store
  +dbs          DBS replicas (the full modified engine)
  +fused        single-program engine step (core/fused.py): admission, CoW,
                mirrored stores, reads and retirement in ONE compiled
                program per batch — no host hop between admission and
                completion

Rows (layer cuts): frontend-only (null backend) / without-storage (null
storage) / full engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import Engine, EngineConfig, Request, UpstreamEngine

COLUMNS = ("upstream", "+frontend", "+comm", "+dbs", "+fused")
ROWS = ("frontend_only", "without_storage", "full_engine")


def make_engine(column: str, row: str, *, payload_shape=(64,),
                n_replicas: int = 2, page_blocks: int = 32,
                n_extents: int = 4096, max_pages: int = 1024):
    null_backend = row == "frontend_only"
    null_storage = row == "without_storage"
    kw = dict(payload_shape=payload_shape, n_replicas=n_replicas,
              page_blocks=page_blocks, n_extents=n_extents,
              max_pages=max_pages, null_backend=null_backend,
              null_storage=null_storage)
    if column == "upstream":
        return UpstreamEngine(EngineConfig(**kw))
    if column == "+frontend":
        return Engine(EngineConfig(storage="chained", comm="loop", **kw))
    if column == "+comm":
        return Engine(EngineConfig(storage="chained", comm="slots", **kw))
    if column == "+dbs":
        return Engine(EngineConfig(storage="dbs", comm="slots", **kw))
    if column == "+fused":
        return Engine(EngineConfig(storage="dbs", comm="fused", **kw))
    raise ValueError(column)


def run_ladder(*, n_requests: int = 512, payload_elems: int = 64,
               kind: str = "mixed", pages: int = 256,
               repeats: int = 1, warmup: bool = True
               ) -> Dict[str, Dict[str, float]]:
    """Returns ops/sec for every (column, row) cell.

    ``warmup`` drains one full write batch and one read batch before the
    timed run so every column is measured steady-state (jit compilation of
    the batch-geometry programs happens once, outside the clock) — the
    paper's fio numbers are steady-state too.
    """
    payload = jnp.ones((payload_elems,), jnp.float32)
    out: Dict[str, Dict[str, float]] = {}
    rng = np.random.default_rng(0)
    page_seq = rng.integers(0, pages, size=n_requests)
    for col in COLUMNS:
        out[col] = {}
        for row in ROWS:
            best = 0.0
            for _ in range(repeats):
                eng = make_engine(col, row, payload_shape=(payload_elems,),
                                  max_pages=pages)
                vol = eng.create_volume()
                if warmup:
                    cap = getattr(eng.cfg, "batch", 64)
                    for i in range(cap):
                        eng.submit(Request(req_id=i, kind="write", volume=vol,
                                           page=i % pages, block=i % 8,
                                           payload=payload))
                    for i in range(cap):
                        eng.submit(Request(req_id=cap + i, kind="read",
                                           volume=vol, page=i % pages,
                                           block=i % 8))
                    eng.drain()
                    eng.completed = 0
                for i in range(n_requests):
                    k = ("write" if (kind == "write" or
                                     (kind == "mixed" and i % 2)) else "read")
                    eng.submit(Request(req_id=i, kind=k, volume=vol,
                                       page=int(page_seq[i]),
                                       block=i % 8, payload=payload))
                t0 = time.perf_counter()
                done = eng.drain()
                dt = time.perf_counter() - t0
                assert done == n_requests, (col, row, done)
                best = max(best, n_requests / dt)
            out[col][row] = best
    return out


def snapshot_degradation(*, n_snapshots=(0, 4, 16, 64), n_reads: int = 256,
                         pages: int = 64) -> Dict[str, List[dict]]:
    """Reads vs snapshot count. Two metrics per point:

    - ops/s (wall time; at CPU scale dict walks are ~ns, so this mostly
      shows engine overheads),
    - **layers touched per read** — the structural cost the paper describes
      ("reads may have to go through the whole chain"): grows linearly for
      the chained sparse-file-style store, constant 1 for DBS's flattened
      in-memory extent map.
    All data is written *before* the first snapshot, so chained reads must
    walk to the bottom of the chain — the paper's worst case.
    """
    res: Dict[str, List[dict]] = {"chained": [], "dbs": []}
    payload = jnp.ones((16,), jnp.float32)
    rng = np.random.default_rng(0)
    for col, key in (("+comm", "chained"), ("+dbs", "dbs")):
        for ns in n_snapshots:
            eng = make_engine(col, "full_engine", payload_shape=(16,),
                              max_pages=pages, n_extents=pages * (ns + 2) + 64)
            vol = eng.create_volume()
            for p in range(pages):              # base data in the oldest layer
                eng.submit(Request(req_id=p, kind="write", volume=vol,
                                   page=p, block=0, payload=payload))
            eng.drain()
            for s in range(ns):                 # empty-ish newer layers
                eng.snapshot(vol)
                eng.submit(Request(req_id=0, kind="write", volume=vol,
                                   page=0, block=0, payload=payload))
                eng.drain()
            for i in range(n_reads):
                eng.submit(Request(req_id=i, kind="read", volume=vol,
                                   page=int(rng.integers(1, pages)), block=0))
            t0 = time.perf_counter()
            done = eng.drain()
            dt = time.perf_counter() - t0
            if key == "chained":
                store = eng.backend.stores[0]
                walked = sum(s.layers_walked for s in eng.backend.stores)
                nreads = sum(s.reads for s in eng.backend.stores)
                depth = walked / max(nreads, 1)
            else:
                depth = 1.0                     # one table gather, always
            res[key].append({"snapshots": ns, "ops_per_s": done / dt,
                             "layers_per_read": depth})
    return res
