# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark entry point:

  table1 (IOPS ladder)      -> paper Table I analogue + snapshot degradation
  table2 (bandwidth ladder) -> paper Table II analogue
  kernels                   -> reference-path microbenches
  roofline                  -> rendered from results/*.json when present
"""
from __future__ import annotations

import os


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import table1_iops, table2_bandwidth, kernels_bench
    for r in table1_iops.run(n_requests=256):
        name = f"{r['bench']}/{r['column']}/{r['layer']}/{r['kind']}"
        derived = f"{r['ops_per_s']:.0f}ops/s"
        if "layers_per_read" in r:
            derived += f";{r['layers_per_read']:.1f}layers/read"
        print(f"{name},{r['us_per_call']:.1f},{derived}", flush=True)
    for r in table2_bandwidth.run(n_extents_io=24):
        name = f"{r['bench']}/{r['column']}/{r['layer']}/{r['kind']}"
        print(f"{name},{r['us_per_call']:.1f},{r['mb_per_s']:.1f}MB/s",
              flush=True)
    for r in kernels_bench.run():
        name = f"{r['bench']}/{r['column']}/{r['layer']}/{r['kind']}"
        print(f"{name},{r['us_per_call']:.1f},-", flush=True)
    path = "results/roofline_single.json"
    if os.path.exists(path):
        from benchmarks import roofline
        print("\n# roofline (single-pod, from dry-run artifacts)")
        print(roofline.render(roofline.load(path)))


if __name__ == "__main__":
    main()
