"""Table II analogue: sequential whole-extent transfers (bandwidth ladder)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.ladder import COLUMNS, ROWS, make_engine
from repro.core import Request

# one "1 MB extent" analogue: page_blocks x block payload
PAGE_BLOCKS = 32
BLOCK_ELEMS = 256          # 1 KiB fp32 per block -> 32 KiB per extent


def run(n_extents_io: int = 64, warmup: bool = True) -> List[dict]:
    """``warmup`` runs the whole workload once before the timed pass so every
    column is measured steady-state (jit compiles happen off the clock),
    mirroring benchmarks/ladder.py."""
    payload = jnp.ones((BLOCK_ELEMS,), jnp.float32)
    bytes_per_req = BLOCK_ELEMS * 4 * PAGE_BLOCKS
    rows = []
    for kind in ("read", "write"):
        for col in COLUMNS:
            for row in ROWS:
                eng = make_engine(col, row, payload_shape=(BLOCK_ELEMS,),
                                  page_blocks=PAGE_BLOCKS,
                                  max_pages=n_extents_io + 2,
                                  n_extents=4 * n_extents_io + 16)
                vol = eng.create_volume()
                # sequential: all blocks of extent e, then extent e+1, ...
                reqs = []
                rid = 0
                for e in range(n_extents_io):
                    for b in range(PAGE_BLOCKS):
                        reqs.append(Request(req_id=rid, kind=kind, volume=vol,
                                            page=e, block=b, payload=payload))
                        rid += 1
                if kind == "read" and row == "full_engine":
                    for r in reqs:    # populate before reading
                        eng.submit(Request(req_id=r.req_id, kind="write",
                                           volume=vol, page=r.page,
                                           block=r.block, payload=payload))
                    eng.drain()
                if warmup:            # compile pass, off the clock
                    for r in reqs:
                        eng.submit(r)
                    eng.drain()
                    eng.completed = 0
                for r in reqs:
                    eng.submit(r)
                t0 = time.perf_counter()
                done = eng.drain()
                dt = time.perf_counter() - t0
                mbps = done / PAGE_BLOCKS * bytes_per_req / dt / 1e6
                rows.append({"bench": "table2_bandwidth", "kind": kind,
                             "layer": row, "column": col, "mb_per_s": mbps,
                             "us_per_call": dt / max(done, 1) * 1e6})
    return rows


def main():
    for r in run():
        print(f"{r['bench']},{r['column']},{r['layer']},{r['kind']},"
              f"{r['us_per_call']:.1f},{r['mb_per_s']:.1f}")


if __name__ == "__main__":
    main()
