"""Kernel micro-benchmarks (interpret-mode wall-times are NOT TPU times;
reported for regression tracking of the reference paths)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _t(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _dbs_rows(key):
    """One write + one read row per REGISTERED DBS kernel, with nominal
    achieved bytes/s (kernels/dbs ``dbs_write_bytes``/``dbs_read_bytes`` —
    implementation-independent, so the ratios compare across kernels)."""
    from repro.core import dbs
    from repro.kernels.dbs import (dbs_read_bytes, dbs_write_bytes,
                                   make_kernel)
    from repro.kernels.dbs.registry import available_kernels
    e, page, d, b = 257, 8, 64, 32          # +1 reserved scratch row
    ks = jax.random.split(key, 3)
    pool = jax.random.normal(ks[0], (e, page, d))
    payload = jax.random.normal(ks[1], (b, d))
    blocks = (jnp.arange(b, dtype=jnp.int32) * 3) % page
    dst = (jnp.arange(b, dtype=jnp.int32) * 5) % (e - 1)
    cow_src = jnp.where(jnp.arange(b) % 4 == 0,
                        (dst + 97) % (e - 1), -1).astype(jnp.int32)
    ok = jnp.arange(b) % 8 != 7
    ext = jnp.where(jnp.arange(b) % 5 == 0, -1, dst).astype(jnp.int32)
    itemsize = pool.dtype.itemsize
    wbytes = dbs_write_bytes(int(ok.sum()), int(((cow_src >= 0) & ok).sum()),
                             page, d, itemsize)
    rbytes = dbs_read_bytes(b, d, itemsize)
    rows = []
    for name in available_kernels():
        kern = make_kernel(name)
        wf = jax.jit(lambda p, pay, dd, cc, oo, bl, k=kern: k.write(
            p, dbs.WriteOps(dst=dd, cow_src=cc, ok=oo), pay, bl))
        rf = jax.jit(lambda p, ee, bl, k=kern: k.read(p, ee, bl))
        w_us = _t(wf, pool, payload, dst, cow_src, ok, blocks)
        r_us = _t(rf, pool, ext, blocks)
        rows.append({"bench": "kernel_dbs", "column": name, "layer": "B32",
                     "kind": "write", "us_per_call": w_us,
                     "bytes_per_s": wbytes / (w_us * 1e-6)})
        rows.append({"bench": "kernel_dbs", "column": name, "layer": "B32",
                     "kind": "read", "us_per_call": r_us,
                     "bytes_per_s": rbytes / (r_us * 1e-6)})
    return rows


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    rows.extend(_dbs_rows(key))
    from repro.kernels.flash_attention.ops import flash_attention_reference
    q = jax.random.normal(key, (1, 512, 8, 64))
    k = jax.random.normal(key, (1, 512, 2, 64))
    v = jax.random.normal(key, (1, 512, 2, 64))
    rows.append({"bench": "kernel_ref", "column": "flash_attention",
                 "layer": "S512", "kind": "fwd",
                 "us_per_call": _t(lambda a, b, c: flash_attention_reference(
                     a, b, c), q, k, v)})
    from repro.kernels.paged_attention import paged_attention_reference
    pk = jax.random.normal(key, (64, 32, 2, 64))
    bt = jnp.arange(48).reshape(4, 12).astype(jnp.int32)
    ln = jnp.full((4,), 360, jnp.int32)
    qd = jax.random.normal(key, (4, 8, 64))
    rows.append({"bench": "kernel_ref", "column": "paged_attention",
                 "layer": "P12", "kind": "decode",
                 "us_per_call": _t(lambda a: paged_attention_reference(
                     a, pk, pk, bt, ln), qd)})
    return rows


def main():
    for r in run():
        bps = f"{r['bytes_per_s']:.3g}" if "bytes_per_s" in r else "-"
        print(f"{r['bench']},{r['column']},{r['layer']},{r['kind']},"
              f"{r['us_per_call']:.1f},{bps}")


if __name__ == "__main__":
    main()
