"""Kernel micro-benchmarks (interpret-mode wall-times are NOT TPU times;
reported for regression tracking of the reference paths)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _t(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    from repro.kernels.flash_attention.ops import flash_attention_reference
    q = jax.random.normal(key, (1, 512, 8, 64))
    k = jax.random.normal(key, (1, 512, 2, 64))
    v = jax.random.normal(key, (1, 512, 2, 64))
    rows.append({"bench": "kernel_ref", "column": "flash_attention",
                 "layer": "S512", "kind": "fwd",
                 "us_per_call": _t(lambda a, b, c: flash_attention_reference(
                     a, b, c), q, k, v)})
    from repro.kernels.paged_attention import paged_attention_reference
    pk = jax.random.normal(key, (64, 32, 2, 64))
    bt = jnp.arange(48).reshape(4, 12).astype(jnp.int32)
    ln = jnp.full((4,), 360, jnp.int32)
    qd = jax.random.normal(key, (4, 8, 64))
    rows.append({"bench": "kernel_ref", "column": "paged_attention",
                 "layer": "P12", "kind": "decode",
                 "us_per_call": _t(lambda a: paged_attention_reference(
                     a, pk, pk, bt, ln), qd)})
    return rows


def main():
    for r in run():
        print(f"{r['bench']},{r['column']},{r['layer']},{r['kind']},"
              f"{r['us_per_call']:.1f},-")


if __name__ == "__main__":
    main()
