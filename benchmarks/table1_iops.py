"""Table I analogue: random single-block ops/s through the engine ladder."""
from __future__ import annotations

from benchmarks.ladder import ROWS, COLUMNS, run_ladder, snapshot_degradation


def run(n_requests: int = 384) -> list:
    rows = []
    for kind in ("read", "write"):
        res = run_ladder(n_requests=n_requests, payload_elems=64, kind=kind)
        for row in ROWS:
            for col in COLUMNS:
                rows.append({
                    "bench": "table1_iops", "kind": kind, "layer": row,
                    "column": col, "ops_per_s": res[col][row],
                    "us_per_call": 1e6 / res[col][row],
                })
    deg = snapshot_degradation()
    for key, series in deg.items():
        for rec in series:
            rows.append({"bench": "snapshot_degradation", "kind": "read",
                         "layer": f"snapshots={rec['snapshots']}",
                         "column": key, "ops_per_s": rec["ops_per_s"],
                         "us_per_call": 1e6 / rec["ops_per_s"],
                         "layers_per_read": rec["layers_per_read"]})
    return rows


def main():
    for r in run():
        print(f"{r['bench']},{r['column']},{r['layer']},{r['kind']},"
              f"{r['us_per_call']:.1f},{r['ops_per_s']:.0f}")


if __name__ == "__main__":
    main()
