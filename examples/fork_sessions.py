"""Session forking = DBS snapshots + copy-on-write (paper §IV-D on HBM).

A parent session generates; we fork it twice mid-stream. Forks share the
parent's KV pages (no copy) until one of them writes into the shared tail
page — at which point DBS allocates a fresh extent and the dbs_copy kernel
performs the CoW, exactly like Longhorn snapshot semantics on disk. Greedy
decoding proves isolation: every fork continues the parent's stream
identically.

Run:  PYTHONPATH=src python examples/fork_sessions.py
"""
import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import dbs
from repro.models import init_params
from repro.serving import GenRequest, ServeEngine

cfg = smoke_config("granite-3-8b")
params = init_params(jax.random.PRNGKey(0), cfg)
eng = ServeEngine(cfg, params, n_slots=6, max_len=96)

rng = np.random.default_rng(3)
eng.submit(GenRequest(req_id=0,
                      prompt=rng.integers(0, cfg.vocab_size, size=(10,)),
                      max_new=14))
for _ in range(4):
    eng.step()
print("parent after 4 steps:", eng.live[0].out_tokens)
print("DBS:", dbs.stats(eng.state))

c1 = eng.fork(0, 1, max_new=8)
c2 = eng.fork(0, 2, max_new=10)
print(f"forked twice (volumes {c1.volume}, {c2.volume}) — "
      f"pages shared, snapshots: {dbs.stats(eng.state)['snapshots']}")

for _ in range(16):
    eng.step()

p = eng.live[0].out_tokens
print("parent:", p)
for rid in (1, 2):
    c = eng.live[rid].out_tokens
    marker = "== parent prefix" if c == p[:len(c)] else "!! DIVERGED"
    print(f"fork {rid}: {c}  {marker}")
    assert c == p[:len(c)], "CoW isolation broken"
print("final DBS:", dbs.stats(eng.state))
print("fork_sessions OK")
