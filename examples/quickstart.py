"""Quickstart: the whole system in 60 lines.

Builds a reduced granite-family model, trains it a few steps on synthetic
data, checkpoints to a replicated DBS store, restarts, and serves the result
through the paged-KV engine (DBS volumes + slot scheduler + multi-queue
admission) — the full Longhorn-engine-on-TPU data path at laptop scale.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import ExecutionPlan, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.serving import GenRequest, ServeEngine
from repro.training.trainer import Trainer

cfg = smoke_config("granite-3-8b")
plan = ExecutionPlan(remat="none", compute_dtype="float32")

with tempfile.TemporaryDirectory() as tmp:
    dirs = [os.path.join(tmp, d) for d in "ab"]
    for d in dirs:
        os.makedirs(d)

    print(f"== training {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) ==")
    data = SyntheticLM(cfg.vocab_size, batch=4, seq=32)
    trainer = Trainer(cfg, plan, data, ckpt_dirs=dirs, ckpt_every=5,
                      total_steps=40, warmup=2)
    hist = trainer.run(15)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({trainer.step} steps, checkpointed to {len(dirs)} replicas)")
    trainer.ckpt.close()

    print("== restart: resume from the replicated DBS checkpoint ==")
    trainer2 = Trainer(cfg, plan, data, ckpt_dirs=dirs, ckpt_every=5,
                       total_steps=40, warmup=2)
    assert trainer2.step == trainer.step
    print(f"resumed at step {trainer2.step}")

    print("== serving with paged-DBS KV cache ==")
    eng = ServeEngine(cfg, trainer2.params, n_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(GenRequest(
            req_id=rid, prompt=rng.integers(0, cfg.vocab_size, size=(8,)),
            max_new=8))
    outs = eng.run(max_steps=30)
    for rid, toks in sorted(outs.items()):
        print(f"request {rid}: {toks}")
    trainer2.ckpt.close()
    print("quickstart OK")
