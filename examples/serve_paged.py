"""Serve a small model with batched requests through the paged-DBS engine.

Shows the serving data path of DESIGN.md: multi-queue admission -> slot
table -> DBS page allocation (control plane) -> paged decode (data plane),
with more requests than slots so continuous batching has to recycle.

Run:  PYTHONPATH=src python examples/serve_paged.py
"""
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import dbs
from repro.models import init_params
from repro.serving import GenRequest, ServeEngine

cfg = smoke_config("gemma2-2b")          # softcaps + local/global layers
params = init_params(jax.random.PRNGKey(0), cfg)
eng = ServeEngine(cfg, params, n_slots=4, max_len=96, n_queues=2)

rng = np.random.default_rng(7)
N = 10
t0 = time.time()
for rid in range(N):
    eng.submit(GenRequest(
        req_id=rid,
        prompt=rng.integers(0, cfg.vocab_size, size=(6 + rid % 9,)),
        max_new=8))

outs = eng.run(max_steps=80)
dt = time.time() - t0
total = sum(len(v) for v in outs.values())
print(f"served {N} requests / {total} tokens in {dt:.1f}s "
      f"({total/dt:.1f} tok/s, {eng.n_slots} slots, "
      f"{len(eng.frontend.queues)} admission queues)")
for rid, toks in sorted(outs.items()):
    print(f"  req {rid}: {toks}")
st = dbs.stats(eng.state)
print(f"DBS after drain: {st} (no extent leaks)")
assert st["extents_used"] == 0
