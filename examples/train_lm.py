"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A granite-family decoder (~110M params) on synthetic Zipf data with the real
training stack: chunked CE, remat, AdamW + warmup-cosine, replicated DBS
checkpoints every 50 steps, straggler accounting. Loss should fall from
~ln(V) toward the Zipf entropy.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import os
import time

from repro.configs import ExecutionPlan
from repro.configs.base import ArchConfig, ATTN_GLOBAL
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.training.trainer import Trainer

CFG_100M = ArchConfig(
    name="granite-100m", family="dense",
    n_layers=8, d_model=640, n_heads=10, n_kv_heads=2, head_dim=64,
    d_ff=2560, vocab_size=32_000, layer_pattern=(ATTN_GLOBAL,),
    activation="silu", gated_mlp=True, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    plan = ExecutionPlan(remat="block", compute_dtype="bfloat16",
                         param_dtype="float32", microbatches=1,
                         logits_chunk=64)
    dirs = [os.path.join(args.ckpt_dir, d) for d in "ab"]
    for d in dirs:
        os.makedirs(d, exist_ok=True)
    data = Prefetcher(SyntheticLM(cfg.vocab_size, args.batch, args.seq),
                      depth=2)
    tr = Trainer(cfg, plan, data, ckpt_dirs=dirs, ckpt_every=50,
                 lr=3e-4, warmup=50, total_steps=args.steps)
    t0 = time.time()
    hist = tr.run(args.steps)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps in {dt:.0f}s "
          f"({toks/dt:.0f} tok/s on CPU), stragglers: {tr.straggler_events}")
    for h in hist[:: max(1, len(hist) // 12)]:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f} ({h['step_time_s']:.2f}s)")
    print(f"final loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")
    tr.ckpt.close()
    data.close()


if __name__ == "__main__":
    main()
